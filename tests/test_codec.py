"""Codec-aware replication: wire-byte math, per-link negotiation, the
codec="none" byte-identity invariant, int8 wire reduction through the engine,
control-plane sync compression, the real-array encode/decode path, and the
kernel-vs-reference bit-identity pairing (tentpole + satellites 1/2, PR 6)."""
import math
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core import SimCluster, random_edge_topology, run_trace_sim
from repro.core import codec as wire_codec
from repro.core.engine import ChurnEvent, SimBackend
from repro.core.plans import build_plan
from repro.scenarios import poisson_churn

MB = 1024 * 1024
ROOT = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# Wire-byte math + negotiation (the cost model).
# ---------------------------------------------------------------------------


def test_wire_bytes_none_is_identity():
    for p in (0, 1, 17, 4096, 128 * MB):
        assert wire_codec.wire_bytes(wire_codec.CODEC_NONE, p) == p


def test_wire_bytes_int8_formula_and_asymptote():
    p = 128 * MB
    elems = math.ceil(p / 4)
    blocks = math.ceil(elems / wire_codec.Q_BLOCK)
    expect = elems + blocks * wire_codec.SCALE_BYTES
    assert wire_codec.wire_bytes(wire_codec.CODEC_INT8, p) == expect
    # Per-shard framing floor: 4 payload bytes become 1 code byte + a
    # 4/256-amortized scale — ~3.94×, which is why the CI bar is ≥3×.
    assert 3.9 < p / expect < 4.0


def test_wire_bytes_int8_topk_keeps_fraction_plus_indices():
    p = 64 * MB
    elems = p // 4
    kept = max(1, int(elems * wire_codec.TOPK_KEEP_FRAC))
    blocks = math.ceil(elems / wire_codec.Q_BLOCK)
    expect = kept * (1 + wire_codec.TOPK_INDEX_BYTES) + blocks * wire_codec.SCALE_BYTES
    assert wire_codec.wire_bytes(wire_codec.CODEC_INT8_TOPK, p) == expect
    assert p / expect > 10  # much sparser than plain int8


def test_wire_bytes_tiny_payloads_never_zero_or_negative():
    for codec in wire_codec.CODECS:
        for p in (1, 2, 3, 4, 5, 255, 256, 257):
            w = wire_codec.wire_bytes(codec, p)
            assert w >= 1, (codec, p, w)


def test_codec_compute_charges_zero_only_for_none():
    p = 32 * MB
    assert wire_codec.encode_s(wire_codec.CODEC_NONE, p) == 0.0
    assert wire_codec.decode_s(wire_codec.CODEC_NONE, p) == 0.0
    for codec in (wire_codec.CODEC_INT8, wire_codec.CODEC_INT8_TOPK):
        assert wire_codec.encode_s(codec, p) > 0.0
        assert wire_codec.decode_s(codec, p) > 0.0
    # top-k pays an extra selection pass over plain int8.
    assert (wire_codec.encode_s(wire_codec.CODEC_INT8_TOPK, p)
            > wire_codec.encode_s(wire_codec.CODEC_INT8, p))


def test_effective_per_byte_derates_fast_links_less():
    """On a fast link the encode/decode compute dominates and compression
    stops paying; on a slow link the wire saving dominates."""
    fast = 1.0 / (2000 * wire_codec.MBPS)  # s/byte on a 2 Gbps link
    slow = 1.0 / (50 * wire_codec.MBPS)
    assert (wire_codec.effective_trans_s_per_byte(wire_codec.CODEC_INT8, slow)
            < slow)
    assert (wire_codec.effective_trans_s_per_byte(wire_codec.CODEC_NONE, fast)
            == fast)


def test_negotiate_auto_picks_by_bandwidth_class():
    assert wire_codec.negotiate("auto", 10_000.0) == wire_codec.CODEC_NONE
    assert wire_codec.negotiate("auto", 2000.0) == wire_codec.CODEC_NONE
    assert wire_codec.negotiate("auto", 500.0) == wire_codec.CODEC_INT8
    assert wire_codec.negotiate("auto", 150.0) == wire_codec.CODEC_INT8
    assert wire_codec.negotiate("auto", 20.0) == wire_codec.CODEC_INT8_TOPK


def test_negotiate_forced_policy_wins_over_bandwidth():
    for bw in (10.0, 500.0, 10_000.0):
        assert wire_codec.negotiate("int8", bw) == wire_codec.CODEC_INT8
        assert wire_codec.negotiate("none", bw) == wire_codec.CODEC_NONE


def test_validate_policy_rejects_unknown():
    with pytest.raises(ValueError):
        wire_codec.validate_policy("gzip")
    with pytest.raises(ValueError):
        SimCluster(random_edge_topology(4, seed=0), state_bytes=MB,
                   tensor_sizes=[MB], codec="zstd")


# ---------------------------------------------------------------------------
# Plans carry wire accounting; "none" plans are byte-for-byte legacy.
# ---------------------------------------------------------------------------


def _plan(codec):
    topo = random_edge_topology(8, seed=0)
    new = 100
    topo.add_node(new)
    for p, bw in ((1, 400.0), (2, 600.0), (3, 250.0)):
        from repro.core.topology import Link
        topo.add_link(p, new, Link(bw, 0.01))
    return build_plan("chaos", topo, new, 64 * MB, [2 * MB] * 32, {},
                      codec=codec)


def test_plan_none_has_no_wire_fields_and_legacy_summary():
    plan = _plan("none")
    assert not plan.codec_active()
    assert plan.wire_sources == {}
    assert plan.codecs == {}
    assert "codecs" not in plan.summary()
    assert "wire_bytes" not in plan.summary()
    for u in plan.sources:
        assert plan.wire_for(u) == plan.sources[u]  # wire == payload


def test_plan_int8_wire_undercuts_payload_shard_aligned():
    plan = _plan("int8")
    assert plan.codec_active()
    s = plan.summary()
    assert set(s["codecs"]) == {str(u) for u in plan.sources}
    for u, payload in plan.sources.items():
        wire = plan.wire_for(u)
        assert wire < payload
        # Per-shard framing: n whole shards + remainder, each encoded
        # independently so partial credit can decode delivered prefixes.
        shard = plan.shard_size
        n_whole, rem = divmod(payload, shard)
        expect = n_whole * wire_codec.wire_bytes("int8", shard)
        if rem:
            expect += wire_codec.wire_bytes("int8", rem)
        assert wire == expect
        assert plan.wire_shard_for(u) == wire_codec.wire_bytes("int8", shard)
    assert plan.total_wire_bytes() < sum(plan.sources.values())


# ---------------------------------------------------------------------------
# Engine: byte-identity under "none", reduction + determinism under int8.
# ---------------------------------------------------------------------------


def _churny_cluster(seed=0):
    return SimCluster(random_edge_topology(16, seed=seed),
                      state_bytes=32 * MB, tensor_sizes=[MB] * 32)


def _churny_trace(seed=0):
    topo = random_edge_topology(16, seed=seed)
    return poisson_churn(topo.active_nodes(), seed=seed + 3, horizon_s=600.0,
                         rate_join=0.05, rate_leave=0.04)


def _churny_replay(omniscient_digest, codec=None, seed=0):
    kw = {} if codec is None else {"codec": codec}
    return omniscient_digest(lambda: _churny_cluster(seed),
                             _churny_trace(seed), **kw)


def test_codec_none_ledger_byte_identical_to_codec_less_engine(omniscient_digest):
    """The tentpole invariant: codec="none" reproduces the pre-codec ledger
    bytes exactly — same trace, same seed, a run that never mentions a
    codec vs one that passes codec="none" explicitly."""
    l_default = _churny_replay(omniscient_digest, codec=None)
    l_none = _churny_replay(omniscient_digest, codec="none")
    assert l_default.canonical_bytes() == l_none.canonical_bytes()
    assert l_default.digest() == l_none.digest()
    assert l_default.actions().count("ready") >= 3  # real work happened


def test_codec_int8_same_seed_byte_identical(same_seed_pair):
    same_seed_pair(lambda: _churny_cluster(0), _churny_trace(0),
                   codec="int8")


def test_codec_int8_ledger_carries_wire_fields_none_does_not(omniscient_digest):
    l_none = _churny_replay(omniscient_digest, codec="none")
    l_int8 = _churny_replay(omniscient_digest, codec="int8")
    none_started = [r for r in l_none if r.action == "scale-out-started"]
    int8_started = [r for r in l_int8 if r.action == "scale-out-started"]
    assert all("codec" not in r.detail for r in none_started)
    assert all(r.detail["codec"] == "int8" for r in int8_started)
    for r in int8_started:
        payload = sum(r.detail["plan"]["sources"].values())
        assert 0 < r.detail["wire_bytes_total"] < payload
    ready = [r for r in l_int8 if r.action == "ready"]
    assert ready and all(r.detail["wire_delivered_bytes"] > 0 for r in ready)


def test_codec_int8_cuts_wire_bytes_3x_and_join_delay():
    def join(codec):
        topo = random_edge_topology(8, seed=0)
        cl = SimCluster(topo, state_bytes=128 * MB,
                        tensor_sizes=[2 * MB] * 64)
        cl.train(1)
        ev = ChurnEvent(t=cl.sim.now, kind="join", node=100,
                        links={1: (200.0, 0.01), 2: (200.0, 0.01),
                               3: (200.0, 0.02)})
        _, results = run_trace_sim(cl, [ev], codec=codec)
        return results[0].delay_s, cl.scheduler.replication_wire_bytes

    none_delay, none_wire = join("none")
    int8_delay, int8_wire = join("int8")
    assert none_wire == 128 * MB  # wire == payload without a codec
    assert none_wire / int8_wire >= 3.0
    assert int8_delay < none_delay  # the saved bytes show up on the clock


def test_churn_event_codec_json_roundtrip():
    ev = ChurnEvent(t=1.5, kind="join", node=7,
                    links={1: (100.0, 0.01)}, codec="int8")
    d = ev.to_json()
    assert d["codec"] == "int8"
    back = ChurnEvent.from_json(d)
    assert back.codec == "int8"
    # Absent codec stays absent (legacy traces parse unchanged).
    ev2 = ChurnEvent(t=1.5, kind="join", node=7, links={1: (100.0, 0.01)})
    assert "codec" not in ev2.to_json()
    assert ChurnEvent.from_json(ev2.to_json()).codec is None


def test_join_event_codec_overrides_scheduler_policy():
    topo = random_edge_topology(8, seed=0)
    cl = SimCluster(topo, state_bytes=32 * MB, tensor_sizes=[MB] * 32)
    cl.train(1)
    ev = ChurnEvent(t=cl.sim.now, kind="join", node=100,
                    links={1: (200.0, 0.01), 2: (300.0, 0.01)},
                    codec="int8")
    ledger, _ = run_trace_sim(cl, [ev])  # engine policy stays "none"
    started = [r for r in ledger if r.action == "scale-out-started"][0]
    assert started.detail["codec"] == "int8"


# ---------------------------------------------------------------------------
# Control plane: deputy sync snapshots compress under the codec too.
# ---------------------------------------------------------------------------


def test_sync_payload_compresses_with_scheduler_policy():
    from repro.core.control import SYNC_BYTES

    def backend(codec):
        topo = random_edge_topology(8, seed=0)
        cl = SimCluster(topo, state_bytes=MB, tensor_sizes=[MB])
        return SimBackend(cl, codec=codec)

    b_none = backend("none")
    b_int8 = backend("int8")
    assert b_none.control._sync_payload_bytes() == SYNC_BYTES
    compressed = b_int8.control._sync_payload_bytes()
    assert compressed == wire_codec.wire_bytes(wire_codec.CODEC_INT8,
                                               SYNC_BYTES)
    assert compressed < SYNC_BYTES / 3


# ---------------------------------------------------------------------------
# Satellite 1: shard-codec grid fallback on awkward block counts.
# ---------------------------------------------------------------------------


def test_block_rows_largest_divisor_within_cap():
    from repro.kernels.shard_codec import _block_rows

    assert _block_rows(300, 256) == 150
    assert _block_rows(510, 256) == 255
    assert _block_rows(1000, 256) == 250
    assert _block_rows(7, 256) == 7
    assert _block_rows(64, 256) == 64
    assert _block_rows(257, 256) == 1  # prime > cap: nothing divides
    for nb in (1, 2, 3, 5, 12, 30, 97, 300, 510, 777, 1000):
        r = _block_rows(nb, 256)
        assert 1 <= r <= min(256, nb)
        assert nb % r == 0


@pytest.mark.parametrize("nb", [1, 7, 97, 300, 510, 1000])
def test_shard_codec_roundtrip_awkward_block_counts(nb):
    """Regression for the degenerate grid: awkward nb used to collapse to
    single-row blocks; now it must pick the largest divisor ≤ 256 AND stay
    bit-identical to the reference through encode/decode."""
    from repro.kernels.ref import shard_codec_ref, shard_decode_ref
    from repro.kernels.shard_codec import (
        shard_decode_kernel,
        shard_encode_kernel,
    )
    import jax.numpy as jnp

    rng = np.random.default_rng(nb)
    x = jnp.asarray(rng.normal(size=(nb, 256)).astype(np.float32))
    c, s = shard_encode_kernel(x)
    cr, sr = shard_codec_ref(x)
    assert np.array_equal(np.asarray(c), np.asarray(cr))
    assert np.array_equal(np.asarray(s), np.asarray(sr))
    d = shard_decode_kernel(c, s)
    dr = shard_decode_ref(cr, sr)
    assert np.array_equal(np.asarray(d), np.asarray(dr))
    # Round-trip error within the documented bound (fp32 slack included).
    err = np.abs(np.asarray(d) - np.asarray(x))
    bound = np.asarray(s)[:, None] / 2.0
    assert np.all(err <= bound * (1 + 1e-5))


# ---------------------------------------------------------------------------
# Satellite 2: int8_quantize ⇄ kernel pairing is bit-identical; dequantize
# honors its documented max-error guarantee.
# ---------------------------------------------------------------------------


def test_quantize_pairs_bit_identical_with_kernel_encode():
    """Property sweep: over shapes, magnitudes, and degenerate values, the
    jnp quantizer and the Pallas encode kernel produce bit-identical codes
    AND scales (the contract the real-array transfer path asserts)."""
    from repro.kernels.shard_codec import shard_encode_kernel
    from repro.optim.compression import Q_BLOCK, int8_quantize
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    cases = []
    for shape in [(256,), (300, 17), (1000,), (5, 7, 11), (4096,)]:
        for mag in (1e-6, 1.0, 1e4):
            cases.append((rng.normal(size=shape) * mag).astype(np.float32))
    cases.append(np.zeros(512, np.float32))  # scale floor path
    cases.append(np.full(300, 7.25, np.float32))
    for x in cases:
        codes, scales, _ = int8_quantize(jnp.asarray(x))
        pad = (-x.size) % Q_BLOCK
        xf = np.pad(x.reshape(-1), (0, pad)).reshape(-1, Q_BLOCK)
        kc, ks = shard_encode_kernel(jnp.asarray(xf))
        assert np.array_equal(np.asarray(kc), np.asarray(codes)), x.shape
        assert np.array_equal(np.asarray(ks), np.asarray(scales)), x.shape


def test_dequantize_max_error_within_documented_bound():
    from repro.optim.compression import int8_dequantize, int8_quantize
    import jax.numpy as jnp

    rng = np.random.default_rng(1)
    x = (rng.normal(size=(3000,)) * 5.0).astype(np.float32)
    codes, scales, meta = int8_quantize(jnp.asarray(x))
    back = np.asarray(int8_dequantize(codes, scales, meta))
    err = np.abs(back - x)
    per_elem_bound = np.repeat(np.asarray(scales), 256)[: x.size] / 2.0
    assert np.all(err <= per_elem_bound * (1 + 1e-5))


def test_dequantize_integer_dtype_rounds_not_truncates():
    from repro.optim.compression import int8_dequantize, int8_quantize
    import jax.numpy as jnp

    x = np.arange(512, dtype=np.int32) - 256
    codes, scales, meta = int8_quantize(jnp.asarray(x, jnp.float32))
    meta = (x.shape, np.dtype(np.int32))
    back = np.asarray(int8_dequantize(codes, scales, meta))
    assert back.dtype == np.int32
    # Round-to-nearest: error ≤ scale/2 + 1/2, not the doubled truncation
    # error a raw cast would produce.
    bound = np.repeat(np.asarray(scales), 256)[: x.size] / 2.0 + 0.5
    assert np.all(np.abs(back - x) <= bound + 1e-3)


# ---------------------------------------------------------------------------
# Real-array transfer path: encode/decode shard buffers.
# ---------------------------------------------------------------------------


def _mixed_tree():
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    return {
        "w": jnp.asarray(rng.normal(size=(300, 17)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(1000,)).astype(np.float32)),
        "step": jnp.asarray(7, np.int32),
        "half": jnp.asarray(rng.normal(size=(64,)).astype(np.float16)),
        "lr": jnp.asarray(1e-3, jnp.float32),
    }


def test_encode_state_int8_reduces_wire_and_bounds_error():
    import jax
    from repro.core.replication import (
        decode_state,
        encode_state,
        roundtrip_max_error_ok,
    )

    tree = _mixed_tree()
    leaves, manifest, wire = encode_state(tree, "int8", verify_kernel=True)
    payload = sum(l.payload_bytes for l in leaves)
    assert payload == manifest.total_bytes
    assert payload / wire > 3.0  # fp32-dominated tree
    # fp32 leaves quantize; everything else ships raw (exactness contract).
    kinds = {e.path: l.kind for e, l in zip(manifest.entries, leaves)}
    assert kinds["w"] == kinds["b"] == kinds["lr"] == "int8"
    assert kinds["step"] == kinds["half"] == "raw"
    decoded = decode_state(leaves, manifest, verify_kernel=True)
    assert roundtrip_max_error_ok(tree, decoded, leaves)
    for o, d in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(decoded)):
        assert np.asarray(o).shape == np.asarray(d).shape
        assert np.asarray(o).dtype == np.asarray(d).dtype


def test_encode_state_none_is_lossless_passthrough():
    import jax
    from repro.core.replication import decode_state, encode_state

    tree = _mixed_tree()
    leaves, manifest, wire = encode_state(tree, "none")
    assert all(l.kind == "raw" for l in leaves)
    assert wire == manifest.total_bytes
    decoded = decode_state(leaves, manifest)
    for o, d in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(decoded)):
        assert np.array_equal(np.asarray(o), np.asarray(d))


# ---------------------------------------------------------------------------
# ElasticTrainer: the codec rides real scale-outs; installed state is exact.
# ---------------------------------------------------------------------------


def test_trainer_validates_codec_policy():
    from repro.core.sharding_alg import NeighborLink
    from repro.elastic.trainer import ElasticTrainer

    class _Dev:
        def __init__(self, i):
            self.id = i

    with pytest.raises(ValueError):
        ElasticTrainer(None, devices=[_Dev(0), _Dev(1)], initial=2,
                       link_model=lambda i: NeighborLink(0.001, 1e-8),
                       codec="brotli")


@pytest.mark.slow
def test_trainer_scale_out_int8_reports_wire_and_installs_exact_state():
    """Real-array acceptance: a codec="int8" scale-out encodes the shard
    buffers through the codec (kernel equivalence asserted inside), reports
    >3× wire reduction, and still installs bit-exact state (training
    continues unperturbed — synchronous DP replicas must not diverge)."""
    code = """
        import numpy as np
        import jax
        from repro.configs import get_config
        from repro.elastic import ElasticTrainer
        from repro.models import build_model

        cfg = get_config("gpt2").reduced()
        tr = ElasticTrainer(build_model(cfg), initial=2, codec="int8")
        tr.init()
        before = jax.tree_util.tree_map(lambda x: np.asarray(x), tr.state)
        ev = tr.scale_out()
        cs = ev.plan_summary["codec"]
        assert cs["codec"] == "int8", cs
        assert cs["wire_reduction"] > 3.0, cs
        assert cs["wire_bytes"] < cs["payload_bytes"]
        after = jax.tree_util.tree_map(lambda x: np.asarray(x), tr.state)
        for a, b in zip(jax.tree_util.tree_leaves(before),
                        jax.tree_util.tree_leaves(after)):
            assert np.array_equal(a, b)  # lossy install would diverge DP
        assert len(tr.active) == 3
        print("OK trainer-codec", cs["wire_reduction"])
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(ROOT / "src")
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=420, env=env)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "OK trainer-codec" in res.stdout
