"""Partial-transfer credit: cancelled shard streams keep their delivered
shard-aligned prefix, re-plans cover exactly the missing bytes, ledgers stay
byte-identical per seed, and degraded links reshape plans on both backends."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.core import SimCluster, random_edge_topology, run_trace_sim
from repro.core.engine import ChurnEngine, ChurnEvent
from repro.core.plans import plan_assignment
from repro.core.sharding_alg import NeighborLink
from repro.core.simulator import TransferHandle
from repro.scenarios import adversarial_churn, bandwidth_degradation

MB = 1024 * 1024
ROOT = Path(__file__).resolve().parent.parent


def _cluster(n=8, seed=0, state=128 * MB, tensor=2 * MB, strategy="chaos"):
    topo = random_edge_topology(n, seed=seed)
    return SimCluster(topo, state_bytes=state,
                      tensor_sizes=[tensor] * (state // tensor),
                      strategy=strategy)


def _join_then_link_failure(cl, *, fail_after=1.0, partial_credit=True):
    cl.train(1)
    t0 = cl.sim.now
    links = {1: (400.0, 0.01), 2: (600.0, 0.01), 3: (250.0, 0.02)}
    events = [
        ChurnEvent(t=t0 + 0.1, kind="join", node=100, links=links),
        ChurnEvent(t=t0 + 0.1 + fail_after, kind="link-failure", u=2, v=100),
    ]
    return run_trace_sim(cl, events, partial_credit=partial_credit)


# ---------------------------------------------------------------------------
# TransferHandle progress model.
# ---------------------------------------------------------------------------


def test_transfer_handle_progress_is_linear_on_final_hop():
    from repro.core.simulator import Network, Sim
    from repro.core.topology import Link, Topology

    sim, topo = Sim(), Topology()
    topo.add_node(0), topo.add_node(1)
    link = Link(800.0, 0.01)  # 100 MB/s
    topo.add_link(0, 1, link)
    net = Network(sim, topo)
    h = net.transfer([0, 1], 10 * MB, lambda t: None)
    t0 = h.t_first_byte
    assert t0 == pytest.approx(link.latency_s)
    assert h.progress(t0) == 0.0
    half = t0 + 5 * MB / link.bytes_per_s
    assert h.progress(half) == pytest.approx(5 * MB)
    assert h.progress(t0 + 1e9) == 10 * MB  # clamped to payload size
    h.cancel(half)
    assert h.cancelled_delivered == pytest.approx(5 * MB)


def test_cancel_before_launch_credits_nothing():
    h = TransferHandle()
    h.cancel(123.0)
    assert h.cancelled_delivered == 0.0


# ---------------------------------------------------------------------------
# Credit accounting through the engine.
# ---------------------------------------------------------------------------


def test_cancelled_stream_credits_whole_shards_and_replans_the_rest():
    """A cancelled stream with N delivered shards re-plans exactly
    total − delivered bytes, with the credit floored to shard boundaries."""
    cl = _cluster()
    state = cl.state_bytes
    ledger, results = _join_then_link_failure(cl)
    rep = [r for r in ledger if r.action == "replanned"]
    assert len(rep) == 1
    d = rep[0].detail
    started = [r for r in ledger if r.action == "scale-out-started"][0]
    shard = started.detail["plan"]["shard_size"]
    assert shard > 0
    # Credit is a whole number of original-plan shards, and positive.
    assert d["credited_bytes"] > 0
    assert d["credited_bytes"] % shard == 0
    # The re-plan covers exactly the missing bytes: total − delivered,
    # where delivered = completed streams + credited prefixes.
    assert d["replanned_bytes"] == state - d["delivered_bytes"]
    assert d["credited_bytes"] <= d["delivered_bytes"]
    # The new plan moves at least the missing bytes (shard-rounded up),
    # and the overshoot is bounded by one new shard per source.
    new_plan = d["plan"]
    planned = sum(new_plan["sources"].values())
    n_sources = len(new_plan["sources"])
    assert planned >= d["replanned_bytes"]
    assert planned - d["replanned_bytes"] <= new_plan["shard_size"] * n_sources
    # The join still completes, and the severed source is out of the plan.
    assert "ready" in ledger.actions()
    assert "2" not in new_plan["sources"]
    assert results[0].replans == 1


def test_partial_credit_strictly_shrinks_replanned_bytes_and_delay():
    pre_ledger, pre_res = _join_then_link_failure(
        _cluster(), partial_credit=False)
    post_ledger, post_res = _join_then_link_failure(
        _cluster(), partial_credit=True)
    pre = [r for r in pre_ledger if r.action == "replanned"][0].detail
    post = [r for r in post_ledger if r.action == "replanned"][0].detail
    assert pre["credited_bytes"] == 0
    assert post["credited_bytes"] > 0
    assert post["replanned_bytes"] < pre["replanned_bytes"]
    assert post_res[0].delay_s <= pre_res[0].delay_s
    # Final ready records agree with the replan-time accounting.
    pre_ready = [r for r in pre_ledger if r.action == "ready"][0].detail
    post_ready = [r for r in post_ledger if r.action == "ready"][0].detail
    assert pre_ready["credited_bytes"] == 0
    assert post_ready["credited_bytes"] == post["credited_bytes"]


def test_codec_int8_credit_is_wire_shard_aligned_and_replans_fewer_bytes():
    """Churn mid-replication under codec="int8": the cancelled stream's
    credit is a whole number of *wire* shards (each wire shard decodes to
    exactly one payload shard — per-shard framing), the payload and wire
    credits agree on the shard count, and the credit-aware re-plan moves
    strictly fewer bytes than the pre-credit forfeit under the same codec."""
    from repro.core import codec as wire_codec

    def replay(partial_credit):
        cl = _cluster()
        cl.train(1)
        t0 = cl.sim.now
        links = {1: (400.0, 0.01), 2: (600.0, 0.01), 3: (250.0, 0.02)}
        events = [
            ChurnEvent(t=t0 + 0.1, kind="join", node=100, links=links),
            ChurnEvent(t=t0 + 0.9, kind="link-failure", u=2, v=100),
        ]
        return run_trace_sim(cl, events, partial_credit=partial_credit,
                             codec="int8")

    ledger, results = replay(True)
    started = [r for r in ledger if r.action == "scale-out-started"][0].detail
    rep = [r for r in ledger if r.action == "replanned"][0].detail
    shard = started["plan"]["shard_size"]
    wire_shard = wire_codec.wire_bytes(wire_codec.CODEC_INT8, shard)
    assert started["codec"] == rep["codec"] == "int8"
    assert started["wire_bytes_total"] < cl_state_bytes_of(started)
    # Credit is whole shards in BOTH spaces, and the counts agree: n wire
    # shards delivered ⇒ n payload shards installed.
    assert rep["credited_bytes"] > 0
    assert rep["credited_bytes"] % shard == 0
    assert rep["credited_wire_bytes"] % wire_shard == 0
    assert rep["credited_bytes"] // shard == \
        rep["credited_wire_bytes"] // wire_shard
    # The re-plan ships compressed bytes: wire strictly below payload.
    assert rep["replanned_wire_bytes"] < rep["replanned_bytes"]
    # Against the pre-credit forfeit (same codec): strictly fewer bytes,
    # in payload and on the wire.
    pre_ledger, _ = replay(False)
    pre = [r for r in pre_ledger if r.action == "replanned"][0].detail
    assert pre["credited_bytes"] == 0
    assert rep["replanned_bytes"] < pre["replanned_bytes"]
    assert rep["replanned_wire_bytes"] < pre["replanned_wire_bytes"]
    # The join completes and reports codec-aware delivery accounting.
    ready = [r for r in ledger if r.action == "ready"][0].detail
    assert ready["codec"] == "int8"
    assert ready["wire_delivered_bytes"] > 0
    assert results[0].replans == 1


def cl_state_bytes_of(started_detail):
    """Payload total of the started plan (sources sum) — the wire total
    must undercut it for any non-``none`` codec."""
    return sum(started_detail["plan"]["sources"].values())


def test_link_degrade_mid_replication_triggers_credit_aware_reshuffle():
    cl = _cluster()
    cl.train(1)
    t0 = cl.sim.now
    events = [
        ChurnEvent(t=t0 + 0.1, kind="join", node=100,
                   links={1: (400.0, 0.01), 2: (600.0, 0.01)}),
        ChurnEvent(t=t0 + 1.1, kind="link-degrade", u=2, v=100,
                   bandwidth_mbps=20.0),
    ]
    ledger, results = run_trace_sim(cl, events)
    actions = ledger.actions()
    assert "link-degraded" in actions
    assert "replanned" in actions
    assert "ready" in actions
    started = [r for r in ledger if r.action == "scale-out-started"][0]
    rep = [r for r in ledger if r.action == "replanned"][0]
    assert rep.detail["credited_bytes"] > 0
    # The degraded link changes the plan shape: the slow source now carries
    # fewer of the remaining bytes than the healthy one.
    new_sources = rep.detail["plan"]["sources"]
    assert new_sources != started.detail["plan"]["sources"]
    assert new_sources.get("2", 0) < new_sources.get("1", 0)
    assert results[0].replans == 1
    # The degraded link's new rate landed in the topology.
    assert cl.topo.link(2, 100).bandwidth_mbps == 20.0


def test_degrade_of_untouched_link_does_not_replan():
    cl = _cluster(10)
    cl.train(1)
    t0 = cl.sim.now
    others = [n for n in cl.topo.active_nodes() if n not in (1, 2)]
    u = [n for n in others if cl.topo.neighbors(n)][0]
    v = cl.topo.neighbors(u)[0]
    events = [
        ChurnEvent(t=t0 + 0.1, kind="join", node=100,
                   links={1: (400.0, 0.01), 2: (600.0, 0.01)}),
        ChurnEvent(t=t0 + 1.0, kind="link-degrade", u=u, v=v,
                   bandwidth_mbps=10.0),
    ]
    ledger, results = run_trace_sim(cl, events)
    assert "link-degraded" in ledger.actions()
    assert "replanned" not in ledger.actions()
    assert results[0].replans == 0


def test_abort_still_forfeits_credit_free():
    """The joining node dying aborts outright — credit never resurrects a
    replication whose target is gone."""
    cl = _cluster()
    cl.train(1)
    t0 = cl.sim.now
    events = [
        ChurnEvent(t=t0 + 0.1, kind="join", node=100,
                   links={1: (400.0, 0.01), 2: (600.0, 0.01)}),
        ChurnEvent(t=t0 + 1.0, kind="node-failure", node=100),
    ]
    ledger, results = run_trace_sim(cl, events)
    assert "aborted" in ledger.actions()
    assert "ready" not in ledger.actions()
    assert 0 not in results


# ---------------------------------------------------------------------------
# Determinism: credit arithmetic must not break the ledger contract.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ledger_byte_identical_per_seed_with_credit(seed):
    def replay():
        topo = random_edge_topology(16, seed=seed)
        nodes = topo.active_nodes()
        trace = adversarial_churn(nodes, seed=seed + 40, horizon_s=90.0,
                                  n_joins=4, strike_delay_s=1.0)
        cl = SimCluster(topo, state_bytes=128 * MB,
                        tensor_sizes=[2 * MB] * 64)
        cl.train(1)
        ledger, _ = run_trace_sim(cl, trace)
        return ledger

    l1, l2 = replay(), replay()
    assert l1.canonical_bytes() == l2.canonical_bytes()
    assert "replanned" in l1.actions()


def test_bandwidth_degradation_trace_deterministic_and_credits():
    def replay():
        topo = random_edge_topology(12, seed=5)
        trace = bandwidth_degradation(topo.active_nodes(), seed=9,
                                      horizon_s=60.0, n_joins=3)
        cl = SimCluster(topo, state_bytes=128 * MB,
                        tensor_sizes=[4 * MB] * 32)
        cl.train(1)
        return run_trace_sim(cl, trace)[0]

    l1, l2 = replay(), replay()
    assert l1.canonical_bytes() == l2.canonical_bytes()
    credited = sum(r.detail.get("credited_bytes", 0)
                   for r in l1 if r.action == "replanned")
    assert credited > 0


# ---------------------------------------------------------------------------
# TrainerBackend: link events reshape plans on the real-array side.
# ---------------------------------------------------------------------------


class _Dev:
    def __init__(self, i):
        self.id = i


def _stub_trainer(n=4, initial=3):
    from repro.elastic.trainer import ElasticTrainer

    return ElasticTrainer(None, devices=[_Dev(i) for i in range(n)],
                          initial=initial,
                          link_model=lambda i: NeighborLink(0.001, 1e-8, 0.0))


def test_trainer_link_degrade_changes_chosen_plan():
    tr = _stub_trainer()
    sizes = [1 * MB] * 16
    base = plan_assignment(sizes, tr.replication_neighbors())
    tr.apply_link_event("link-degrade", [1], bandwidth_mbps=0.8)
    degraded = plan_assignment(sizes, tr.replication_neighbors())
    assert degraded.shards_per_neighbor != base.shards_per_neighbor
    # The crawling link carries (almost) nothing.
    slow = len(degraded.shards_per_neighbor.get(1, []))
    fast = len(degraded.shards_per_neighbor.get(0, []))
    assert slow < fast
    # Restoring with no parameters returns to the static link model.
    tr.apply_link_event("link-join", [1])
    restored = plan_assignment(sizes, tr.replication_neighbors())
    assert restored.shards_per_neighbor == base.shards_per_neighbor


def test_trainer_backend_routes_link_events_to_device_overrides():
    from repro.elastic.trainer import TrainerBackend

    tr = _stub_trainer()
    engine = ChurnEngine(TrainerBackend(tr, min_active=1))
    ledger = engine.run([
        ChurnEvent(t=1.0, kind="link-degrade", u=1, v=99, bandwidth_mbps=5.0),
        ChurnEvent(t=2.0, kind="link-failure", u=2, v=0),
        ChurnEvent(t=3.0, kind="link-join", u=50, v=60),  # unresolvable
    ])
    assert ledger.actions() == ["link-degraded", "link-severed", "noop-link"]
    assert tr.effective_link(1).trans_s_per_byte > 1e-8  # degraded
    assert tr.effective_link(2).trans_s_per_byte >= 1.0  # severed
    assert tr.effective_link(0).trans_s_per_byte >= 1.0  # other endpoint too
    # Devices named by the record are deterministic ids.
    assert ledger.records[0].detail == {"devices": [1], "bandwidth_mbps": 5.0}


def test_trainer_backend_severed_then_restored_link_plan_roundtrip():
    from repro.elastic.trainer import TrainerBackend

    tr = _stub_trainer()
    sizes = [1 * MB] * 12
    base = plan_assignment(sizes, tr.replication_neighbors())
    engine = ChurnEngine(TrainerBackend(tr, min_active=1))
    engine.run([ChurnEvent(t=1.0, kind="link-failure", u=1, v=99)])
    severed = plan_assignment(sizes, tr.replication_neighbors())
    assert len(severed.shards_per_neighbor.get(1, [])) == 0
    engine.run([ChurnEvent(t=2.0, kind="link-join", u=1, v=99)])
    healed = plan_assignment(sizes, tr.replication_neighbors())
    assert healed.shards_per_neighbor == base.shards_per_neighbor


def test_trainer_overlapping_impairments_do_not_clobber_each_other():
    """Restoring one link must not erase another link's still-active sever
    on the same device (overlapping link_flaps on a focal node)."""
    from repro.elastic.trainer import TrainerBackend

    tr = _stub_trainer()
    engine = ChurnEngine(TrainerBackend(tr, min_active=1))
    engine.run([
        ChurnEvent(t=1.0, kind="link-failure", u=1, v=50),
        ChurnEvent(t=2.0, kind="link-failure", u=1, v=60),
        ChurnEvent(t=3.0, kind="link-join", u=1, v=50),  # heal first flap
    ])
    # The (1, 60) sever is still in force.
    assert tr.effective_link(1).trans_s_per_byte >= 1.0
    engine.run([ChurnEvent(t=4.0, kind="link-join", u=1, v=60)])
    assert tr.effective_link(1).trans_s_per_byte == pytest.approx(1e-8)


@pytest.mark.slow
def test_bandwidth_degradation_replay_changes_trainer_plan_shape():
    """Acceptance: replay_scenario on a bandwidth_degradation trace yields a
    different plan shape than the undegraded baseline on real JAX devices —
    join 1's degraded link reshapes join 2's replication plan."""
    code = """
        from repro.configs import get_config
        from repro.core.sharding_alg import NeighborLink
        from repro.elastic import ElasticTrainer
        from repro.models import build_model
        from repro.scenarios import bandwidth_degradation

        trace = bandwidth_degradation(range(3), seed=4, horizon_s=50.0,
                                      n_joins=2, drop_factor=0.01)
        assert trace.kinds() == {"join": 2, "link-degrade": 2}, trace.kinds()
        # Seed chosen so join 1's drop lands before join 2 (the trainer
        # applies events sequentially; only later joins see the degradation).
        order = [e.kind for e in sorted(trace, key=lambda e: e.t)]
        assert order == ["join", "link-degrade", "join", "link-degrade"], order
        baseline = [e for e in trace if e.kind != "link-degrade"]

        def replay(events):
            cfg = get_config("gpt2").reduced()
            tr = ElasticTrainer(build_model(cfg), initial=3,
                                link_model=lambda i: NeighborLink(0.001, 1e-9))
            tr.init()
            tr.replay_scenario(events, min_active=1)
            return [ev.plan_summary["bytes_per_source"]
                    for ev in tr.events if ev.kind == "scale-out"]

        degraded = replay(list(trace))
        undegraded = replay(baseline)
        assert len(degraded) == len(undegraded) == 2
        # Join 1 plans before any degradation: identical shape.
        assert degraded[0] == undegraded[0], (degraded, undegraded)
        # Join 2 plans after join 1's best link collapsed: different shape.
        assert degraded[1] != undegraded[1], (degraded, undegraded)
        print("OK degraded-plan-shape", degraded[1], undegraded[1])
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    env["PYTHONPATH"] = str(ROOT / "src")
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=420, env=env)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "OK degraded-plan-shape" in res.stdout
