"""Checkpoint tier under churn: shard-aligned credit on cancelled pushes,
restore A/B (replica vs checkpoint) down to bit-identical trainer state,
adaptive cadence responding to measured fault arrivals, and the
``AsyncCheckpointer`` restore/GC race regression."""
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, MemoryReplicaStore
from repro.core import Link, SimCluster, Topology
from repro.core.engine import ChurnEvent, SimBackend, run_trace_sim
from repro.core.goodput import CKPT_BASE_INTERVAL_S, SimCheckpointTier

MB = 2 ** 20


def _line_topology():
    """0 —(100 Mbps)— 1, 0 —(50 Mbps)— 2: home 0's best direct link is to 1,
    so the checkpoint tier's holder pick is deterministic."""
    topo = Topology()
    for n in (0, 1, 2):
        topo.add_node(n, compute_s=1.0)
    topo.add_link(0, 1, Link(100.0, 0.001))
    topo.add_link(0, 2, Link(50.0, 0.001))
    topo.add_link(1, 2, Link(100.0, 0.001))
    return topo


def _ckpt_cluster():
    return SimCluster(_line_topology(), state_bytes=32 * MB,
                      tensor_sizes=[1 * MB] * 32)


def _records(ledger, action):
    return [r for r in ledger if r.action == action]


# ---------------------------------------------------------------------------
# Partial credit: a push cancelled mid-stream keeps whole delivered shards.
# ---------------------------------------------------------------------------


def test_cancelled_push_gets_shard_aligned_credit():
    cl = _ckpt_cluster()
    cl.train(1)
    t0 = cl.sim.now
    # Push fires at t0+1, bytes flow from t0+1.25; degrading the 0–1 link
    # one second into the stream cancels it with ~12 MB on the wire.
    events = [ChurnEvent(t=t0 + 2.25, kind="link-degrade", u=0, v=1,
                         bandwidth_mbps=10.0, latency_s=0.001)]
    ledger, _ = run_trace_sim(cl, events, checkpoint="fixed",
                              ckpt_interval_s=1.0)
    cancelled = _records(ledger, "ckpt-cancelled")
    assert cancelled, "the degrade must land mid-push"
    d = cancelled[0].detail
    assert 0 < d["credited_bytes"] <= d["delivered_bytes"]
    assert d["credited_bytes"] % MB == 0  # whole shards only
    # The resumed push starts from the credit, not from zero ...
    resumed = [r for r in _records(ledger, "ckpt-started")
               if r.detail["credited_bytes"] > 0]
    assert resumed and resumed[0].detail["credited_bytes"] == d["credited_bytes"]
    assert resumed[0].detail["bytes"] == 32 * MB - d["credited_bytes"]
    # ... and every started push reached exactly one terminal record.
    started = len(_records(ledger, "ckpt-started"))
    terminal = len(cancelled) + len(_records(ledger, "ckpt-complete"))
    assert started == terminal
    assert _records(ledger, "ckpt-complete")  # the retry finished


def test_holder_death_forfeits_credit():
    cl = _ckpt_cluster()
    cl.train(1)
    t0 = cl.sim.now
    events = [ChurnEvent(t=t0 + 2.25, kind="node-failure", node=1)]
    ledger, _ = run_trace_sim(cl, events, checkpoint="fixed",
                              ckpt_interval_s=1.0)
    cancelled = _records(ledger, "ckpt-cancelled")
    assert cancelled
    d = cancelled[0].detail
    assert d["holder"] == 1
    assert d["delivered_bytes"] > 0
    assert d["credited_bytes"] == 0  # bytes died with the holder


def test_checkpoint_recovery_ledgers_restore_and_lost_window():
    cl = _ckpt_cluster()
    cl.train(1)
    t0 = cl.sim.now
    # Let one checkpoint complete (~t0+3.9), then crash non-holder node 2.
    events = [ChurnEvent(t=t0 + 8.0, kind="node-failure", node=2)]
    ledger, _ = run_trace_sim(cl, events, checkpoint="fixed",
                              ckpt_interval_s=1.0,
                              policy="fixed-checkpoint")
    assert _records(ledger, "ckpt-complete")
    restored = _records(ledger, "ckpt-restored")
    assert len(restored) == 1
    d = restored[0].detail
    assert d["holder"] == 1
    assert d["restore_s"] > 0.0  # state re-streamed over the sim network
    assert d["lost_from"] <= d["lost_to"]
    assert d["lost_s"] == pytest.approx(d["lost_to"] - d["lost_from"])
    assert not _records(ledger, "replica-restored")


def test_replica_recovery_is_instant_and_lossless():
    cl = _ckpt_cluster()
    cl.train(1)
    t0 = cl.sim.now
    events = [ChurnEvent(t=t0 + 8.0, kind="node-failure", node=2)]
    ledger, _ = run_trace_sim(cl, events, checkpoint="fixed",
                              ckpt_interval_s=1.0, policy="fixed-replica")
    restored = _records(ledger, "replica-restored")
    assert len(restored) == 1
    assert restored[0].detail["restore_s"] == 0.0
    assert restored[0].detail["lost_s"] == 0.0
    assert not _records(ledger, "ckpt-restored")


# ---------------------------------------------------------------------------
# Trace-borne checkpoint events: forwarded to the tier, or skipped cleanly.
# ---------------------------------------------------------------------------


def test_trace_checkpoint_events_drive_the_tier():
    from repro.scenarios import checkpointed_training

    cl = _ckpt_cluster()
    cl.train(1)
    t0 = cl.sim.now
    trace = checkpointed_training([0, 1, 2], seed=9, horizon_s=60.0,
                                  ckpt_every_s=15.0, rate_leave=0.0,
                                  rate_join=0.0)
    events = [ChurnEvent(t=t0 + e.t, kind=e.kind, node=e.node)
              for e in trace]
    # With a tier attached the trace's push requests become real pushes
    # (cadence fires disabled via a huge interval, so every push here is
    # trace-driven) ...
    ledger, _ = run_trace_sim(cl, events, checkpoint="fixed",
                              ckpt_interval_s=10_000.0)
    assert len(_records(ledger, "ckpt-started")) == len(events) == 3
    # ... and without one, each request is a clean ledgered skip.
    cl2 = _ckpt_cluster()
    cl2.train(1)
    ledger2, _ = run_trace_sim(cl2, events)
    skips = _records(ledger2, "ckpt-skipped-no-checkpointer")
    assert len(skips) == len(events)
    assert not _records(ledger2, "ckpt-started")


# ---------------------------------------------------------------------------
# Adaptive cadence: interval shrinks as the measured fault rate grows.
# ---------------------------------------------------------------------------


def test_adaptive_interval_monotone_in_fault_rate():
    cl = _ckpt_cluster()
    cl.train(1)
    be = SimBackend(cl, checkpoint="adaptive")
    tier = be.ckpt
    tier.t0 = tier.sim.now - 100.0  # 100 virtual seconds of history
    assert tier.current_interval() == tier.base_interval_s  # prior = fixed
    seen = []
    for _ in range(5):
        tier.note_fault()
        seen.append(tier.current_interval())
    assert all(a > b for a, b in zip(seen, seen[1:]))  # strictly shrinking
    assert all(s <= tier.base_interval_s for s in seen)


def test_fixed_cadence_ignores_fault_rate():
    cl = _ckpt_cluster()
    cl.train(1)
    be = SimBackend(cl, checkpoint="fixed")
    tier = be.ckpt
    tier.t0 = tier.sim.now - 100.0
    for _ in range(5):
        tier.note_fault()
    assert tier.current_interval() == CKPT_BASE_INTERVAL_S


def test_tier_rejects_unknown_cadence_and_policy():
    cl = _ckpt_cluster()
    cl.train(1)
    be = SimBackend(cl)
    with pytest.raises(ValueError):
        SimCheckpointTier(be, cadence="hourly")
    # The old per-tier recovery knob is gone; action selection lives in the
    # policy layer, which rejects unknown specs and restore actions.
    with pytest.raises(ValueError):
        SimBackend(_ckpt_cluster(), policy="tape")
    with pytest.raises(ValueError):
        SimCheckpointTier(be).restore(0, 1, "restore-tape")


# ---------------------------------------------------------------------------
# Trainer recovery tiers: replica vs checkpoint restore, bit for bit.
# ---------------------------------------------------------------------------


def _tiny_trainer():
    import jax
    import jax.numpy as jnp
    from repro.elastic import ElasticTrainer

    tr = ElasticTrainer(None, devices=jax.devices()[:1], initial=1)
    tr.state = {
        "params": {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                   "b": jnp.full((8,), 0.25, jnp.float32)},
        "opt": {"m": jnp.linspace(-1.0, 1.0, 64, dtype=jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }
    return tr


def _leaves(tree):
    import jax
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


def test_replica_and_checkpoint_restore_bit_identical(tmp_path):
    import jax
    import jax.numpy as jnp

    tr = _tiny_trainer()
    store = MemoryReplicaStore()
    ck = AsyncCheckpointer(tmp_path)
    tr.attach_recovery(replica_store=store, checkpointer=ck, owner=0)
    out = tr.checkpoint(step=7)
    assert out == {"step": 7, "tiers": ["replica", "checkpoint"]}
    golden = _leaves(tr.state)

    def clobber():
        tr.state = jax.tree.map(lambda x: jnp.zeros_like(x), tr.state)

    clobber()
    assert tr.restore_from("replica") == 7
    from_replica = _leaves(tr.state)
    clobber()
    assert tr.restore_from("checkpoint") == 7
    from_ckpt = _leaves(tr.state)
    ck.close()

    for g, a, b in zip(golden, from_replica, from_ckpt):
        assert g.dtype == a.dtype == b.dtype
        assert np.array_equal(g, a)
        assert a.tobytes() == b.tobytes()  # the A/B acceptance: bit-identical


def test_trainer_backend_checkpoint_event_saves_and_skips():
    """The same trace `checkpoint` event drives both substrates: with a
    tier attached it pushes the live state (`ckpt-saved`), without one it
    resolves to the same terminal skip the simulator writes."""
    tr = _tiny_trainer()
    ledger = tr.replay_scenario([ChurnEvent(t=1.0, kind="checkpoint")],
                                min_active=1)
    assert ledger.actions() == ["ckpt-skipped-no-checkpointer"]
    store = MemoryReplicaStore()
    tr.attach_recovery(replica_store=store)
    ledger = tr.replay_scenario([ChurnEvent(t=2.0, kind="checkpoint")],
                                min_active=1)
    assert ledger.actions() == ["ckpt-saved"]
    assert next(iter(ledger)).detail["tiers"] == ["replica"]
    tree, step = store.restore(0)
    assert step == tr.step_count and tree is not None


def test_restore_without_tier_raises():
    tr = _tiny_trainer()
    with pytest.raises(RuntimeError):
        tr.checkpoint()
    tr.attach_recovery(replica_store=MemoryReplicaStore())
    with pytest.raises(RuntimeError):
        tr.restore_from("checkpoint")
    with pytest.raises(ValueError):
        tr.restore_from("tape")


# ---------------------------------------------------------------------------
# AsyncCheckpointer restore/GC race (regression): latest() can name a file
# the background _gc deletes before the open.
# ---------------------------------------------------------------------------


def test_restore_latest_survives_gc_race(tmp_path, monkeypatch):
    ck = AsyncCheckpointer(tmp_path, keep=3)
    for s in (1, 2):
        ck.save(s, {"w": np.full(4, float(s), np.float32)})
    ck.wait()
    real = Path.read_bytes
    raised = []

    def flaky(self):
        # The newest checkpoint vanishes between the scan and the open,
        # exactly as a concurrent _gc would make it.
        if self.name == "step_00000002.ckpt" and not raised:
            raised.append(self)
            raise FileNotFoundError(self)
        return real(self)

    monkeypatch.setattr(Path, "read_bytes", flaky)
    tree, step = ck.restore_latest({"w": np.zeros(4, np.float32)})
    assert raised  # the race actually happened
    assert step == 1  # fell back to the surviving next-newest
    assert np.array_equal(tree["w"], np.full(4, 1.0, np.float32))
    ck.close()


def test_restore_latest_all_candidates_vanish_returns_none(tmp_path, monkeypatch):
    ck = AsyncCheckpointer(tmp_path, keep=3)
    ck.save(1, {"w": np.zeros(2, np.float32)})
    ck.wait()

    def always_gone(self):
        raise FileNotFoundError(self)

    monkeypatch.setattr(Path, "read_bytes", always_gone)
    tree, step = ck.restore_latest({"w": np.zeros(2, np.float32)})
    assert tree is None and step == -1
    monkeypatch.undo()
    ck.close()


def test_restore_latest_empty_dir(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    tree, step = ck.restore_latest({"w": np.zeros(2, np.float32)})
    assert tree is None and step == -1
    ck.close()
