"""Phi-accrual suspicion, adaptive sweeps, and detection traffic riding the
simulated network.

Covers the detection-layer redesign: probes/heartbeats as real (daemon,
non-contending) transfers whose delivery the network delays or drops
organically, the phi suspicion score and its latency bounds, adaptive sweep
backoff/tightening, per-link loss RNG streams, the sweep-generation counter,
partial-loss data-plane goodput inflation, monitor-owned give-up deadlines,
and same-seed determinism with all of it active.
"""
import pytest

from repro.core import ChurnEvent, Link, SimCluster, Topology, random_edge_topology, run_trace_sim
from repro.core.monitor import (
    HEARTBEAT_PERIOD_S,
    PHI_ELEVATED,
    PHI_THRESHOLD,
    SWEEP_MAX_FACTOR,
    SWEEP_TIGHTEN_FACTOR,
    phi_score,
)
from repro.core.simulator import CONTROL_QUEUE_CAP_S, Network, Sim

MB = 1024 * 1024


def _cluster(n=8, seed=0, state=32 * MB, tensor=1 * MB):
    topo = random_edge_topology(n, seed=seed)
    return SimCluster(topo, state_bytes=state,
                      tensor_sizes=[tensor] * (state // tensor))


def _advance(cl, seconds):
    cl.sim.run(until=cl.sim.now + seconds)


def _sweep_times(mon):
    """Wrap check_heartbeats to record executed heartbeat-sweep instants
    (stale-generation chains return before checking, so they don't count)."""
    times = []
    orig = mon.check_heartbeats

    def wrapped():
        times.append(mon.sim.now)
        return orig()

    mon.check_heartbeats = wrapped
    return times


# ---------------------------------------------------------------------------
# Phi score sanity + latency bounds.
# ---------------------------------------------------------------------------


def test_phi_score_monotone_and_calibrated():
    assert phi_score(0.0, 2.0, 0.5) < PHI_ELEVATED
    xs = [phi_score(x, 2.0, 0.5) for x in (1.0, 2.0, 3.0, 4.0, 5.0, 6.0)]
    assert xs == sorted(xs)
    assert phi_score(2.0, 2.0, 0.5) == pytest.approx(0.301, abs=1e-3)
    assert phi_score(6.0, 2.0, 0.5) > PHI_THRESHOLD  # 8 sigma: surely dead


def test_phi_detection_faster_under_churn_no_worse_quiet():
    """The acceptance-criterion shape, pinned against the *same* scenario
    the CI smoke A/B runs (benchmarks.common.measure_detection_latency —
    not a re-implementation that could drift): adaptive phi-accrual
    detects a silent node death faster than the fixed-timeout baseline
    while churn keeps the sweeps tightened, and no later when quiet."""
    common = pytest.importorskip(
        "benchmarks.common", reason="benchmarks importable from repo root")
    sizes = common.tensor_sizes_for(16 * MB, 1 * MB)

    def detect(detector, congested):
        return common.measure_detection_latency(
            8, 16 * MB, sizes, seed=0, detector=detector,
            congested=congested)["detection_s"]

    assert detect("phi", True) < detect("fixed", True)
    assert detect("phi", False) <= detect("fixed", False) + 1e-9


# ---------------------------------------------------------------------------
# Adaptive sweep periods: back off when quiet, tighten under suspicion.
# ---------------------------------------------------------------------------


def test_sweeps_back_off_when_quiet_and_tighten_on_suspicion():
    cl = _cluster()
    mon = cl.scheduler.monitor
    times = _sweep_times(mon)
    mon.start_sweeps()
    _advance(cl, 40.0)
    gaps = [b - a for a, b in zip(times, times[1:])]
    # Quiet: geometric backoff up to the cap (the first gap already
    # carries one backoff step, applied at the first sweep).
    assert gaps[0] == pytest.approx(HEARTBEAT_PERIOD_S * 1.5)
    assert max(gaps) == pytest.approx(HEARTBEAT_PERIOD_S * SWEEP_MAX_FACTOR)
    assert all(b >= a - 1e-9 for a, b in zip(gaps, gaps[1:]))
    # A node going silent raises suspicion: the next sweeps tighten.
    victim = [n for n in cl.topo.active_nodes() if n != cl.scheduler.node][0]
    mon.inject_node_fault(victim)
    n_before = len(times)
    _advance(cl, 30.0)
    tight = [b - a for a, b in zip(times[n_before:], times[n_before + 1:])]
    assert min(tight) == pytest.approx(
        HEARTBEAT_PERIOD_S * SWEEP_TIGHTEN_FACTOR)
    assert victim in mon.faulted_nodes() or victim not in cl.topo.active_nodes()


def test_stop_start_sweeps_does_not_double_the_chain():
    """Satellite: stop_sweeps() then start_sweeps() must leave exactly one
    sweep chain — the orphaned chain self-cancels via the generation
    counter instead of resuming alongside the new one (which would double
    sweep frequency and RNG draws)."""
    cl = _cluster()
    mon = cl.scheduler.monitor
    times = _sweep_times(mon)
    mon.start_sweeps(detector="fixed")  # fixed periods: gaps are exact
    _advance(cl, 7.0)
    mon.stop_sweeps()
    mon.start_sweeps(detector="fixed")
    _advance(cl, 20.0)
    gaps = [b - a for a, b in zip(times, times[1:])]
    assert gaps, times
    # A doubled chain would interleave sweeps at half the period.
    assert min(gaps) >= HEARTBEAT_PERIOD_S - 1e-9
    assert len(times) == len(set(times))


# ---------------------------------------------------------------------------
# Satellite: per-link RNG streams — loss outcomes invariant to churn
# elsewhere in the overlay.
# ---------------------------------------------------------------------------


def test_lossy_link_detection_invariant_to_unrelated_churn():
    """Churn that changes the probe-target list (here: a silent node whose
    links drop out of the sweep) must not reshuffle the loss draws — hence
    the detection time — of an unrelated lossy link."""

    def lossy_detection(extra_fault):
        cl = _cluster(seed=1)
        cl.train(1)
        sched = cl.scheduler.node
        edges = sorted(cl.topo.g.edges)
        lossy = [e for e in edges if sched not in e][0]
        other = [n for n in cl.topo.active_nodes()
                 if n != sched and n not in lossy][0]
        t0 = cl.sim.now
        events = [ChurnEvent(t=t0 + 0.5, kind="link-loss",
                             u=lossy[0], v=lossy[1], loss_rate=0.9)]
        if extra_fault:
            # Same trace time => sweeps start on the same grid; the node
            # fault still reshapes _probe_targets from the first sweep.
            events.append(ChurnEvent(t=t0 + 0.5, kind="node-fault",
                                     node=other))
        ledger, _ = run_trace_sim(cl, events, detector="fixed")
        recs = [r for r in ledger if r.action == "link-failed"
                and tuple(r.subject) == lossy]
        assert recs, ledger.actions()
        return recs[0].detail["detected_t"]

    assert lossy_detection(False) == pytest.approx(lossy_detection(True))


# ---------------------------------------------------------------------------
# Detection traffic rides the network: congestion, blackholes, multipath.
# ---------------------------------------------------------------------------


def test_control_datagram_delayed_by_congestion_but_not_starved():
    """A non-contending datagram behind a bulk transfer waits at most
    CONTROL_QUEUE_CAP_S — congestion shows up in control-plane latency
    without a probe queueing behind an entire replication stream."""
    topo = Topology()
    for i in (0, 1):
        topo.add_node(i)
    link = Link(100.0, 0.01)
    topo.add_link(0, 1, link)
    sim = Sim()
    net = Network(sim, topo)
    bulk_s = link.trans_delay_per_byte * 50 * MB  # ~4 s of occupancy
    net.transfer([0, 1], 50 * MB, lambda t: None)
    got = []
    net.transfer([0, 1], 256.0, got.append, daemon=True, contend=False)
    sim.run()
    assert got, "datagram never delivered"
    expect = CONTROL_QUEUE_CAP_S + link.latency_s + 256 * link.trans_delay_per_byte
    assert got[0] == pytest.approx(expect)
    assert bulk_s > CONTROL_QUEUE_CAP_S  # the cap actually bit


def test_heartbeats_survive_silent_relay_via_disjoint_route():
    """A healthy node whose primary heartbeat route transits a silent
    relay must not be declared dead: the redundant copy rides a
    relay-disjoint route. The relay itself is detected."""
    topo = Topology()
    for i in range(4):
        topo.add_node(i)
    topo.add_link(1, 2, Link(1000.0, 0.001))  # fast: primary 1->2->0
    topo.add_link(2, 0, Link(1000.0, 0.001))
    topo.add_link(1, 3, Link(100.0, 0.02))  # slow alternate 1->3->0
    topo.add_link(3, 0, Link(100.0, 0.02))
    cl = SimCluster(topo, state_bytes=4 * MB, tensor_sizes=[1 * MB] * 4)
    cl.train(1)
    assert cl.scheduler.node == 0
    ledger, _ = run_trace_sim(cl, [
        ChurnEvent(t=cl.sim.now + 0.5, kind="node-fault", node=2)])
    failed = [r.subject for r in ledger if r.action == "node-failed"]
    assert (2,) in failed
    assert (1,) not in failed, ledger.actions()
    assert 1 in cl.topo.active_nodes()


def test_probe_timeout_on_congested_slow_link_is_organic():
    """_probe_ok is gone: a probe fails when its transfer misses the
    deadline. A link degraded to a crawl (latency above the probe timeout)
    organically fails probes and gets detected — no fault table entry."""
    cl = _cluster()
    cl.train(1)
    u, v = [e for e in sorted(cl.topo.g.edges)
            if cl.scheduler.node not in e][0]
    t0 = cl.sim.now
    ledger, _ = run_trace_sim(cl, [
        # Something must start the sweeps (lazy start): a lossless loss
        # fault on another link injects nothing observable.
        ChurnEvent(t=t0 + 0.1, kind="link-loss", loss_rate=0.0,
                   u=[e for e in sorted(cl.topo.g.edges) if e != (u, v)][0][0],
                   v=[e for e in sorted(cl.topo.g.edges) if e != (u, v)][0][1]),
        # Degrade the victim link so its propagation alone exceeds the
        # probe timeout: every probe misses the deadline.
        ChurnEvent(t=t0 + 0.2, kind="link-degrade", u=u, v=v,
                   latency_s=2.0),
    ])
    recs = [r for r in ledger if r.action == "link-failed"
            and tuple(r.subject) == (min(u, v), max(u, v))]
    assert recs, ledger.actions()
    assert recs[0].detail.get("fault_t") is None  # nothing was injected


def test_link_join_restoring_faulted_link_wins_race_against_detection():
    """A silent link-fault never removes the link from the topology, so a
    restoring link-join must clear the pending fault (terminal
    fault-cleared record) instead of being skipped-link-exists — leaving
    the healthy link to be falsely severed by the probes later."""
    cl = _cluster()
    cl.train(1)
    u, v = [e for e in sorted(cl.topo.g.edges)
            if cl.scheduler.node not in e][0]
    link = cl.topo.link(u, v)
    t0 = cl.sim.now
    ledger, _ = run_trace_sim(cl, [
        ChurnEvent(t=t0 + 0.5, kind="link-fault", u=u, v=v),
        # Restored well before the ~3 s probe detection: restoration wins.
        ChurnEvent(t=t0 + 1.0, kind="link-join", u=u, v=v,
                   bandwidth_mbps=link.bandwidth_mbps,
                   latency_s=link.latency_s),
    ])
    actions = ledger.actions()
    assert "link-restored" in actions, actions
    assert "fault-cleared" in actions
    assert "link-failed" not in actions  # the healthy link is NOT severed
    assert "skipped-link-exists" not in actions
    assert cl.topo.has_link(u, v)


def test_link_join_after_detection_reconnects_normally():
    """The other side of the flap race: detection wins, the link is
    severed, and the late link-join re-connects it fresh."""
    cl = _cluster()
    cl.train(1)
    u, v = [e for e in sorted(cl.topo.g.edges)
            if cl.scheduler.node not in e][0]
    link = cl.topo.link(u, v)
    t0 = cl.sim.now
    ledger, _ = run_trace_sim(cl, [
        ChurnEvent(t=t0 + 0.5, kind="link-fault", u=u, v=v),
        ChurnEvent(t=t0 + 8.0, kind="link-join", u=u, v=v,
                   bandwidth_mbps=link.bandwidth_mbps,
                   latency_s=link.latency_s),
    ])
    actions = ledger.actions()
    assert "link-failed" in actions
    assert "link-connected" in actions
    assert cl.topo.has_link(u, v)


# ---------------------------------------------------------------------------
# Partial-loss data-plane goodput (tentpole: SimBackend extension).
# ---------------------------------------------------------------------------


def test_partial_loss_inflates_data_plane_per_byte_time():
    topo = Topology()
    for i in (0, 1):
        topo.add_node(i)
    link = Link(100.0, 0.01)
    topo.add_link(0, 1, link)
    sim = Sim()
    net = Network(sim, topo)
    done = []
    net.transfer([0, 1], 1 * MB, done.append)
    sim.run()
    clean = done[0]
    net.set_link_loss(0, 1, 0.5)
    t0 = sim.now
    net.transfer([0, 1], 1 * MB, done.append)
    sim.run()
    lossy = done[1] - t0
    trans = 1 * MB * link.trans_delay_per_byte
    assert clean == pytest.approx(link.latency_s + trans)
    assert lossy == pytest.approx(link.latency_s + 2 * trans)
    net.clear_link_loss(0, 1)
    t0 = sim.now
    net.transfer([0, 1], 1 * MB, done.append)
    sim.run()
    assert done[2] - t0 == pytest.approx(clean)


def test_partial_link_loss_slows_replication_streams():
    """A silent partial loss on a plan link slows the join's shard stream
    via the goodput factor: the join completes later than the clean run
    even if probe detection never trips (loss below the consecutive
    threshold is possible), with in-flight physics — no replan needed."""

    def ready_time(loss_rate):
        cl = _cluster(state=64 * MB)
        cl.train(1)
        t0 = cl.sim.now
        links = {1: (40.0, 0.01), 2: (50.0, 0.01)}
        events = [ChurnEvent(t=t0 + 0.1, kind="join", node=100, links=links)]
        if loss_rate is not None:
            # After the join created the link, before its shard stream
            # launches (negotiation + measurement + planning take ~0.5 s).
            events.append(ChurnEvent(t=t0 + 0.3, kind="link-loss",
                                     u=2, v=100, loss_rate=loss_rate))
        ledger, _ = run_trace_sim(cl, events)
        ready = [r for r in ledger if r.action == "ready"]
        replanned = [r for r in ledger if r.action == "replanned"]
        return (ready[0].t if ready else None,
                len(replanned), ledger.actions())

    t_clean, _, _ = ready_time(None)
    t_lossy, replans, actions = ready_time(0.4)
    assert t_clean is not None and t_lossy is not None, actions
    assert t_lossy > t_clean  # goodput inflation reached the data plane


def test_giveup_expiry_keeps_world_lossy():
    """fault-undetected ends detection *attribution*, not physics: after
    the drain gives up on a lossy link, its goodput inflation persists
    (matching TrainerBackend, which keeps 1/(1-loss) forever) and probes
    keep being dropped — only link churn repairs the world."""
    cl = _cluster()
    cl.train(1)
    u, v = [e for e in sorted(cl.topo.g.edges)
            if cl.scheduler.node not in e][0]
    ledger, _ = run_trace_sim(cl, [
        # 0.05 loss: two consecutive probe drops (p=0.0025/sweep) are
        # vanishingly unlikely within the give-up window for this seed.
        ChurnEvent(t=cl.sim.now + 0.5, kind="link-loss", u=u, v=v,
                   loss_rate=0.05)])
    assert "fault-undetected" in ledger.actions(), ledger.actions()
    mon = cl.scheduler.monitor
    key = (min(u, v), max(u, v))
    assert key in cl.net._link_loss  # data-plane inflation persists
    assert mon._expired_loss.get(key) == pytest.approx(0.05)
    mon.reset_link(u, v)  # the link itself churns: now the world heals
    assert key not in cl.net._link_loss
    assert key not in mon._expired_loss


def test_inject_then_clear_restores_clean_goodput():
    cl = _cluster()
    mon = cl.scheduler.monitor
    u, v = sorted(cl.topo.g.edges)[0]
    mon.inject_link_loss(u, v, 0.5)
    assert cl.net._link_loss  # inflation installed
    mon.reset_link(u, v)  # e.g. the link churned / re-joined
    assert not cl.net._link_loss


# ---------------------------------------------------------------------------
# Satellite: probe piggybacking on data-plane traffic.
# ---------------------------------------------------------------------------


def test_probe_piggybacking_reduces_control_datagrams():
    """A completed data-plane transfer counts as a fresh probe/heartbeat
    observation for its links and endpoints: the next redundant control
    datagram is skipped, so the same trace costs measurably fewer
    datagrams with piggybacking on — and the join still completes."""

    def run(piggyback):
        cl = _cluster(state=64 * MB)
        cl.train(1)
        mon = cl.scheduler.monitor
        mon.piggyback = piggyback
        u, v = [e for e in sorted(cl.topo.g.edges)
                if cl.scheduler.node not in e][0]
        t0 = cl.sim.now
        events = [
            # Starts the sweeps; loss_rate=0 injects nothing observable.
            ChurnEvent(t=t0 + 0.1, kind="link-loss", u=u, v=v,
                       loss_rate=0.0),
            # Replication bytes on the wire = piggyback evidence.
            ChurnEvent(t=t0 + 0.5, kind="join", node=100,
                       links={1: (200.0, 0.01), 2: (300.0, 0.01)}),
        ]
        ledger, _ = run_trace_sim(cl, events)
        return mon, ledger

    mon_off, ledger_off = run(False)
    mon_on, ledger_on = run(True)
    assert mon_off.piggybacked_probes == 0
    assert mon_off.piggybacked_heartbeats == 0
    skipped = (mon_on.piggybacked_probes + mon_on.piggybacked_heartbeats)
    assert skipped > 0
    assert mon_on.control_datagrams < mon_off.control_datagrams
    assert "ready" in ledger_on.actions()
    assert "ready" in ledger_off.actions()


def test_piggyback_evidence_does_not_mask_blackholed_link():
    """A blackholed link never completes a transfer, so piggybacking can
    never suppress the probes that detect it — the fault is still found."""
    cl = _cluster()
    cl.train(1)
    u, v = [e for e in sorted(cl.topo.g.edges)
            if cl.scheduler.node not in e][0]
    assert cl.scheduler.monitor.piggyback  # default on
    ledger, _ = run_trace_sim(cl, [
        ChurnEvent(t=cl.sim.now + 0.5, kind="link-fault", u=u, v=v)])
    recs = [r for r in ledger if r.action == "link-failed"
            and tuple(r.subject) == (min(u, v), max(u, v))]
    assert recs, ledger.actions()


# ---------------------------------------------------------------------------
# Satellite: stale heartbeat entries of non-live nodes are GC'd.
# ---------------------------------------------------------------------------


def test_stale_heartbeat_entry_of_parked_node_is_dropped():
    """A node in a state outside active/standby can neither beat nor be
    detected; its heartbeat entry must be dropped, not skipped forever."""
    cl = _cluster()
    mon = cl.scheduler.monitor
    victim = [n for n in cl.topo.active_nodes() if n != cl.scheduler.node][0]
    mon.heartbeat(victim)
    cl.topo.nodes[victim].state = "draining"  # neither live nor failed/left
    assert mon.check_heartbeats() == []
    assert victim not in mon.last_heartbeat  # entry GC'd, no leak
    assert victim not in mon._hb_stats
    # And it is never "detected" later off the stale entry.
    cl.sim.after(60.0, lambda: None)
    cl.sim.run()
    assert mon.check_heartbeats() == []


# ---------------------------------------------------------------------------
# Measurement traffic occupies the network only in detected mode.
# ---------------------------------------------------------------------------


def test_measure_links_occupies_network_only_with_sweeps_on():
    cl = _cluster()
    mon = cl.scheduler.monitor
    node = cl.scheduler.node
    peers = cl.topo.neighbors(node)[:2]
    mon.measure_links(node, peers)
    assert not cl.net._link_free  # omniscient mode: bookkeeping only
    mon.start_sweeps()
    mon.measure_links(node, peers)
    assert cl.net._link_free  # iperf bursts reserved real link time


# ---------------------------------------------------------------------------
# Determinism: adaptive periods + network-riding probes stay byte-identical.
# ---------------------------------------------------------------------------


def _stress_ledger(detector):
    from repro.scenarios import detector_stress

    topo = random_edge_topology(10, seed=3)
    trace = detector_stress(topo, seed=11, horizon_s=30.0)
    cl = SimCluster(topo, state_bytes=16 * MB, tensor_sizes=[1 * MB] * 16)
    cl.train(1)
    ledger, _ = run_trace_sim(cl, trace, detector=detector)
    return trace, ledger


@pytest.mark.parametrize("detector", ["fixed", "phi"])
def test_same_seed_detector_stress_byte_identical(detector):
    trace1, l1 = _stress_ledger(detector)
    trace2, l2 = _stress_ledger(detector)
    assert [e.to_json() for e in trace1] == [e.to_json() for e in trace2]
    assert l1.canonical_bytes() == l2.canonical_bytes()
    # The trace exercised the whole detection surface.
    actions = l1.actions()
    assert "fault-injected" in actions
    assert "link-failed" in actions
    assert "node-failed" in actions
    assert "ready" in actions


def test_detector_stress_generator_mixes_severities():
    from repro.scenarios import detector_stress

    topo = random_edge_topology(12, seed=5)
    trace = detector_stress(topo, seed=2, horizon_s=25.0)
    kinds = [e.kind for e in trace]
    assert "link-loss" in kinds
    assert "link-fault" in kinds
    assert "link-join" in kinds  # the flap restores
    assert "node-fault" in kinds
    assert "join" in kinds
    rates = sorted(e.loss_rate for e in trace if e.kind == "link-loss")
    assert rates == sorted(trace.meta["loss_levels"])
    assert min(rates) < 0.5 < max(rates)  # genuinely mixed severities
    ts = [e.t for e in trace]
    assert ts == sorted(ts)


# ---------------------------------------------------------------------------
# Give-up deadlines are monitor-owned and drive the drain.
# ---------------------------------------------------------------------------


def test_detection_horizon_tracks_pending_faults():
    cl = _cluster()
    mon = cl.scheduler.monitor
    assert mon.detection_horizon() is None
    u, v = sorted(cl.topo.g.edges)[0]
    mon.inject_link_fault(u, v)
    h1 = mon.detection_horizon()
    assert h1 is not None and h1 > cl.sim.now
    victim = [n for n in cl.topo.active_nodes() if n != cl.scheduler.node][0]
    mon.inject_node_fault(victim)
    assert mon.detection_horizon() == pytest.approx(min(
        h1, cl.sim.now
        + 16 * HEARTBEAT_PERIOD_S * SWEEP_MAX_FACTOR))  # NODE_GIVEUP_SWEEPS
    # Clearing the faults clears the horizon.
    mon.reset_link(u, v)
    cl.scheduler.monitor.register_leave(victim, failure=True)
    assert mon.detection_horizon() is None
