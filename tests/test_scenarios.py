"""Scenario generators + trace format tests."""
import json

from repro.core import random_edge_topology
from repro.core.engine import ChurnEvent
from repro.scenarios import (
    ScenarioTrace,
    diurnal_waves,
    flash_crowd,
    link_flaps,
    poisson_churn,
    regional_partition,
)


def _jsons(trace):
    return [e.to_json() for e in trace]


def test_generators_are_seed_deterministic():
    topo = random_edge_topology(16, seed=3)
    nodes = topo.active_nodes()
    for mk in (
        lambda: poisson_churn(nodes, seed=5, horizon_s=600.0),
        lambda: diurnal_waves(nodes, seed=5, horizon_s=600.0, period_s=120.0),
        lambda: regional_partition(topo, seed=5, t_cut=10.0, heal_after_s=30.0),
        lambda: flash_crowd(nodes, seed=5, t_start=3.0, n_joins=12),
        lambda: link_flaps(topo, seed=5, horizon_s=600.0, n_flaps=9),
    ):
        assert _jsons(mk()) == _jsons(mk())


def test_trace_save_load_roundtrip(tmp_path):
    topo = random_edge_topology(12, seed=1)
    trace = poisson_churn(topo.active_nodes(), seed=9, horizon_s=900.0)
    p = tmp_path / "t.jsonl"
    trace.save(p)
    loaded = ScenarioTrace.load(p)
    assert loaded.name == trace.name and loaded.seed == trace.seed
    assert _jsons(loaded) == _jsons(trace)
    # JSONL: one valid JSON object per line.
    for line in p.read_text().splitlines():
        json.loads(line)


def test_poisson_churn_event_mix_and_horizon():
    topo = random_edge_topology(24, seed=2)
    trace = poisson_churn(topo.active_nodes(), seed=11, horizon_s=3000.0,
                          rate_join=0.05, rate_leave=0.04)
    kinds = trace.kinds()
    assert kinds.get("join", 0) > 0
    assert kinds.get("leave", 0) + kinds.get("node-failure", 0) > 0
    assert all(0 <= e.t < 3000.0 for e in trace)
    # Leaves never target the protected (scheduler) node.
    sched = min(topo.active_nodes())
    assert all(e.node != sched for e in trace
               if e.kind in ("leave", "node-failure"))


def test_regional_partition_cuts_only_cross_region_links():
    topo = random_edge_topology(20, seed=4, degree=4)
    trace = regional_partition(topo, seed=6, t_cut=5.0, heal_after_s=20.0)
    region = set(trace.meta["region"])
    fails = [e for e in trace if e.kind == "link-failure"]
    heals = [e for e in trace if e.kind == "link-join"]
    assert len(fails) == trace.meta["links_cut"] > 0
    assert len(heals) == len(fails)  # healed partition restores every link
    for e in fails:
        assert (e.u in region) != (e.v in region)
    # Heals restore the original link parameters.
    for e in heals:
        link = topo.link(e.u, e.v)
        assert e.bandwidth_mbps == link.bandwidth_mbps
        assert e.latency_s == link.latency_s


def test_flash_crowd_is_a_join_burst_in_window():
    trace = flash_crowd(range(8), seed=1, t_start=100.0, n_joins=15,
                        window_s=4.0)
    assert len(trace) == 15
    assert all(e.kind == "join" for e in trace)
    assert all(100.0 <= e.t <= 104.0 for e in trace)
    assert len({e.node for e in trace}) == 15  # unique ids
    assert all(e.links for e in trace)


def test_link_flaps_pair_failure_with_restore():
    topo = random_edge_topology(10, seed=8)
    trace = link_flaps(topo, seed=8, horizon_s=300.0, n_flaps=7,
                       flap_len_s=1.5)
    fails = [e for e in trace if e.kind == "link-failure"]
    joins = [e for e in trace if e.kind == "link-join"]
    assert len(fails) == len(joins) == 7
    by_link = {}
    for e in fails:
        by_link.setdefault((min(e.u, e.v), max(e.u, e.v)), []).append(e.t)
    for e in joins:
        key = (min(e.u, e.v), max(e.u, e.v))
        assert key in by_link
        assert topo.has_link(e.u, e.v)


def test_churn_event_json_roundtrip():
    evs = [
        ChurnEvent(t=1.5, kind="join", node=7,
                   links={2: (512.0, 0.01)}, compute_s=1.25),
        ChurnEvent(t=2.0, kind="leave", node=3),
        ChurnEvent(t=2.5, kind="link-join", u=1, v=4,
                   bandwidth_mbps=200.0, latency_s=0.004),
        ChurnEvent(t=3.0, kind="link-failure", u=1, v=4),
    ]
    for e in evs:
        back = ChurnEvent.from_json(json.loads(json.dumps(e.to_json())))
        assert back.to_json() == e.to_json()
