"""Scenario generators + trace format tests."""
import json

import pytest

from repro.core import random_edge_topology
from repro.core.engine import ChurnEvent
from repro.scenarios import (
    ScenarioTrace,
    adversarial_churn,
    bandwidth_degradation,
    checkpointed_training,
    diurnal_waves,
    flash_crowd,
    link_flaps,
    poisson_churn,
    regional_partition,
)


def _jsons(trace):
    return [e.to_json() for e in trace]


def test_generators_are_seed_deterministic():
    topo = random_edge_topology(16, seed=3)
    nodes = topo.active_nodes()
    for mk in (
        lambda: poisson_churn(nodes, seed=5, horizon_s=600.0),
        lambda: diurnal_waves(nodes, seed=5, horizon_s=600.0, period_s=120.0),
        lambda: regional_partition(topo, seed=5, t_cut=10.0, heal_after_s=30.0),
        lambda: flash_crowd(nodes, seed=5, t_start=3.0, n_joins=12),
        lambda: link_flaps(topo, seed=5, horizon_s=600.0, n_flaps=9),
        lambda: adversarial_churn(nodes, seed=5, horizon_s=600.0, n_joins=6),
        lambda: bandwidth_degradation(nodes, seed=5, horizon_s=600.0,
                                      n_joins=5, restore_after_s=10.0),
        lambda: checkpointed_training(nodes, seed=5, horizon_s=600.0),
    ):
        assert _jsons(mk()) == _jsons(mk())


def test_checkpointed_training_mixes_pushes_with_crashes():
    topo = random_edge_topology(16, seed=3)
    trace = checkpointed_training(topo.active_nodes(), seed=5,
                                  horizon_s=200.0, ckpt_every_s=20.0,
                                  jitter_s=0.5)
    kinds = trace.kinds()
    assert kinds.get("checkpoint") == trace.meta["n_ckpts"] == 9
    assert kinds.get("node-failure", 0) >= 1  # the events the pushes insure
    ts = [e.t for e in trace.events]
    assert ts == sorted(ts)
    # Checkpoint requests land near their nominal cadence.
    cts = sorted(e.t for e in trace.events if e.kind == "checkpoint")
    for i, t in enumerate(cts, start=1):
        assert abs(t - 20.0 * i) <= 0.5


def test_trace_save_load_roundtrip(tmp_path):
    topo = random_edge_topology(12, seed=1)
    trace = poisson_churn(topo.active_nodes(), seed=9, horizon_s=900.0)
    p = tmp_path / "t.jsonl"
    trace.save(p)
    loaded = ScenarioTrace.load(p)
    assert loaded.name == trace.name and loaded.seed == trace.seed
    assert _jsons(loaded) == _jsons(trace)
    # JSONL: one valid JSON object per line.
    for line in p.read_text().splitlines():
        json.loads(line)


def test_poisson_churn_event_mix_and_horizon():
    topo = random_edge_topology(24, seed=2)
    trace = poisson_churn(topo.active_nodes(), seed=11, horizon_s=3000.0,
                          rate_join=0.05, rate_leave=0.04)
    kinds = trace.kinds()
    assert kinds.get("join", 0) > 0
    assert kinds.get("leave", 0) + kinds.get("node-failure", 0) > 0
    assert all(0 <= e.t < 3000.0 for e in trace)
    # Leaves never target the protected (scheduler) node.
    sched = min(topo.active_nodes())
    assert all(e.node != sched for e in trace
               if e.kind in ("leave", "node-failure"))


def test_regional_partition_cuts_only_cross_region_links():
    topo = random_edge_topology(20, seed=4, degree=4)
    trace = regional_partition(topo, seed=6, t_cut=5.0, heal_after_s=20.0)
    region = set(trace.meta["region"])
    fails = [e for e in trace if e.kind == "link-failure"]
    heals = [e for e in trace if e.kind == "link-join"]
    assert len(fails) == trace.meta["links_cut"] > 0
    assert len(heals) == len(fails)  # healed partition restores every link
    for e in fails:
        assert (e.u in region) != (e.v in region)
    # Heals restore the original link parameters.
    for e in heals:
        link = topo.link(e.u, e.v)
        assert e.bandwidth_mbps == link.bandwidth_mbps
        assert e.latency_s == link.latency_s


def test_flash_crowd_is_a_join_burst_in_window():
    trace = flash_crowd(range(8), seed=1, t_start=100.0, n_joins=15,
                        window_s=4.0)
    assert len(trace) == 15
    assert all(e.kind == "join" for e in trace)
    assert all(100.0 <= e.t <= 104.0 for e in trace)
    assert len({e.node for e in trace}) == 15  # unique ids
    assert all(e.links for e in trace)


def test_link_flaps_pair_failure_with_restore():
    topo = random_edge_topology(10, seed=8)
    trace = link_flaps(topo, seed=8, horizon_s=300.0, n_flaps=7,
                       flap_len_s=1.5)
    fails = [e for e in trace if e.kind == "link-failure"]
    joins = [e for e in trace if e.kind == "link-join"]
    assert len(fails) == len(joins) == 7
    by_link = {}
    for e in fails:
        by_link.setdefault((min(e.u, e.v), max(e.u, e.v)), []).append(e.t)
    for e in joins:
        key = (min(e.u, e.v), max(e.u, e.v))
        assert key in by_link
        assert topo.has_link(e.u, e.v)


def test_adversarial_churn_strikes_each_joins_best_peer():
    topo = random_edge_topology(16, seed=2)
    trace = adversarial_churn(topo.active_nodes(), seed=7, horizon_s=300.0,
                              n_joins=6)
    events = list(trace)
    joins = [e for e in events if e.kind == "join"]
    strikes = [e for e in events if e.kind in ("leave", "node-failure")]
    assert len(joins) == 6
    assert trace.meta["strikes"] == len(strikes) > 0
    sched = min(topo.active_nodes())
    for s in strikes:
        # The strike follows a join by exactly strike_delay_s and hits that
        # join's highest-bandwidth peer (the largest plan source)...
        src = [j for j in joins
               if j.t == pytest.approx(s.t - trace.meta["strike_delay_s"])]
        assert len(src) == 1
        links = src[0].links
        best = max((bw, p) for p, (bw, _l) in links.items() if p != sched)[1]
        assert s.node == best
        # ...and never the protected scheduler node.
        assert s.node != sched
    # Joins bring ≥ 2 peers, so strikes force re-plans rather than aborts.
    assert all(len(j.links) >= 2 for j in joins)


def test_bandwidth_degradation_drops_each_joins_fastest_link():
    trace = bandwidth_degradation(range(10), seed=4, horizon_s=200.0,
                                  n_joins=5, drop_factor=0.2,
                                  restore_after_s=8.0)
    events = list(trace)
    joins = {e.node: e for e in events if e.kind == "join"}
    degrades = [e for e in events if e.kind == "link-degrade"]
    assert trace.meta["drops"] == 5
    assert len(degrades) == 10  # drop + restore per join
    for d in degrades:
        j = joins[d.v]
        bw, lat = j.links[d.u]
        assert bw == max(b for b, _l in j.links.values())
        assert d.latency_s == lat
        assert d.bandwidth_mbps in (pytest.approx(bw * 0.2), pytest.approx(bw))
    # Every drop is paired with a restore back to the original rate.
    restored = [d for d in degrades
                if d.bandwidth_mbps == pytest.approx(joins[d.v].links[d.u][0])]
    assert len(restored) == 5


def test_churn_event_json_roundtrip():
    evs = [
        ChurnEvent(t=1.5, kind="join", node=7,
                   links={2: (512.0, 0.01)}, compute_s=1.25),
        ChurnEvent(t=2.0, kind="leave", node=3),
        ChurnEvent(t=2.5, kind="link-join", u=1, v=4,
                   bandwidth_mbps=200.0, latency_s=0.004),
        ChurnEvent(t=3.0, kind="link-failure", u=1, v=4),
        ChurnEvent(t=4.0, kind="link-degrade", u=2, v=5,
                   bandwidth_mbps=25.0, latency_s=0.02),
    ]
    for e in evs:
        back = ChurnEvent.from_json(json.loads(json.dumps(e.to_json())))
        assert back.to_json() == e.to_json()
