"""Elastic runtime tests.

The multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test process
keeps its single-device view (per the task spec: never set this globally).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout, env=env)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


@pytest.mark.slow
def test_scale_out_preserves_state_and_loss():
    out = _run("""
        import jax, numpy as np
        from repro.configs import get_config
        from repro.data.synthetic import TokenStream
        from repro.elastic import ElasticTrainer
        from repro.models import build_model

        cfg = get_config("gpt2").reduced()
        model = build_model(cfg)
        stream = TokenStream(vocab=cfg.vocab, seq_len=32, seed=0)
        tr = ElasticTrainer(model, initial=2, per_device_batch=2)
        tr.init()

        def batch():
            return {"tokens": stream.batch(range(tr.global_batch))}

        for _ in range(3):
            m = tr.step(batch())
        before = jax.tree.map(np.asarray, tr.state["params"])
        ev = tr.scale_out()
        after = jax.tree.map(np.asarray, tr.state["params"])
        for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
            np.testing.assert_array_equal(a, b)   # stop-free: state unchanged
        assert len(tr.active) == 3
        assert ev.plan_summary["n_shards"] > 0
        m2 = tr.step(batch())
        assert np.isfinite(m2["loss"]) and abs(m2["loss"] - m["loss"]) < 1.0
        print("OK scale_out", m["loss"], m2["loss"])
    """)
    assert "OK scale_out" in out


@pytest.mark.slow
def test_scale_in_and_failure_recovery():
    out = _run("""
        import jax, numpy as np
        from repro.checkpoint import MemoryReplicaStore
        from repro.configs import get_config
        from repro.core.sharding_alg import NeighborLink
        from repro.data.synthetic import TokenStream
        from repro.elastic import ElasticTrainer
        from repro.models import build_model

        cfg = get_config("gpt2").reduced()
        model = build_model(cfg)
        stream = TokenStream(vocab=cfg.vocab, seq_len=32, seed=0)
        tr = ElasticTrainer(model, initial=4, per_device_batch=2)
        tr.init()
        store = MemoryReplicaStore(redundancy=2)
        nbrs = {i: NeighborLink(0.001, 1e-9) for i in (1, 2, 3)}

        def batch():
            return {"tokens": stream.batch(range(tr.global_batch))}

        for _ in range(3):
            tr.step(batch())
        store.push(owner=0, step=tr.step_count, tree=tr.state, neighbors=nbrs)
        snap = jax.tree.map(np.asarray, tr.state)

        tr.scale_in(failure=True)          # node dies
        store.drop_holder(1)               # including one replica holder
        restored, step = store.restore(0, available=[2, 3])
        for a, b in zip(jax.tree.leaves(snap), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        tr.state = jax.device_put(restored, tr._state_sharding())
        m = tr.step(batch())
        assert np.isfinite(m["loss"])
        assert len(tr.active) == 3
        print("OK failure_recovery", step, m["loss"])
    """)
    assert "OK failure_recovery" in out


@pytest.mark.slow
def test_elastic_loss_continuity_across_churn():
    """Loss stays smooth across join/leave churn (paper Figs 11-14)."""
    out = _run("""
        import numpy as np
        from repro.configs import get_config
        from repro.data.synthetic import ShardedLoader, TokenStream
        from repro.elastic import ElasticTrainer
        from repro.models import build_model
        import dataclasses

        cfg = dataclasses.replace(get_config("gpt2").reduced(), learning_rate=2e-3)
        model = build_model(cfg)
        stream = TokenStream(vocab=cfg.vocab, seq_len=32, seed=0)
        loader = ShardedLoader(stream, 256, [0], batch_per_node=2)
        tr = ElasticTrainer(model, initial=3, per_device_batch=2,
                            on_reshard=lambda ids: loader.reshard(ids))
        tr.init()

        losses = []
        def run(n):
            for _ in range(n):
                toks = np.concatenate([loader.next_batch(i) for i in tr.device_ids()])
                losses.append(tr.step({"tokens": toks})["loss"])

        run(6); tr.scale_out(); run(6); tr.scale_in(); run(6)
        arr = np.asarray(losses)
        assert np.isfinite(arr).all()
        # No catastrophic spike at the churn boundaries.
        jumps = np.abs(np.diff(arr))
        assert jumps.max() < 1.5, jumps
        print("OK continuity", arr[0], arr[-1], jumps.max())
    """)
    assert "OK continuity" in out
