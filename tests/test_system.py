"""End-to-end system tests: the full stack working together — config →
model → optimizer → data → training (loss decreases) → Chaos scale-out plan
→ replication → checkpoint → restore → continue training."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import MemoryReplicaStore, load_checkpoint, save_checkpoint
from repro.configs import SHAPES, get_config, list_configs, ASSIGNED
from repro.configs.base import ShapeCell
from repro.core import (
    Link,
    NeighborLink,
    SimCluster,
    chaos_plan,
    plan_replication,
    execute_replication,
    random_edge_topology,
)
from repro.data.synthetic import TokenStream
from repro.models import build_model


def test_all_assigned_archs_registered():
    known = set(list_configs())
    assert set(ASSIGNED) <= known
    assert {"gpt2", "gpt2-medium", "gpt2-large"} <= known  # paper's own models


def test_shape_cells_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1


def test_long_500k_skip_policy():
    runs = {a for a in ASSIGNED if get_config(a).supports_cell(SHAPES["long_500k"])[0]}
    assert runs == {"rwkv6-1.6b", "zamba2-1.2b"}


@pytest.mark.slow
def test_end_to_end_train_scale_checkpoint_restore(tmp_path):
    """The full story on one device: train → node joins (Chaos plan + real
    replication of the live state) → keep training → checkpoint → crash →
    restore → loss continuity."""
    cfg = dataclasses.replace(get_config("gpt2").reduced(), learning_rate=2e-3)
    model = build_model(cfg)
    state = model.init_train_state(jax.random.PRNGKey(0))
    step = jax.jit(model.make_train_step())
    stream = TokenStream(vocab=cfg.vocab, seq_len=32, seed=0)

    def batch(i, b=8):
        return {"tokens": stream.batch(range(i * b, (i + 1) * b))}

    losses = []
    for i in range(10):
        state, m = step(state, batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-3:]) < np.mean(losses[:3])  # learning

    # --- a node joins: Chaos plans and executes replication of live state ---
    nbrs = {1: NeighborLink(0.002, 1e-8), 2: NeighborLink(0.001, 2e-8)}
    plan = plan_replication(state, nbrs)
    replica, by_source = execute_replication(state, plan)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(replica)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len([u for u, s in by_source.items() if s]) >= 2  # multi-neighbor

    # --- checkpoint, "crash", restore, continue ---
    p = save_checkpoint(tmp_path / "sys.ckpt", state)
    skeleton = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype),
                            jax.tree.map(np.asarray, state))
    restored = load_checkpoint(p, skeleton)
    state2, m2 = step(restored, batch(11))
    assert np.isfinite(m2["loss"])
    assert abs(float(m2["loss"]) - losses[-1]) < 1.0  # no reset to scratch


def test_simulated_cluster_full_lifecycle():
    """Protocol-level lifecycle: train → join → link churn → failure."""
    topo = random_edge_topology(6, seed=2)
    cl = SimCluster(topo, state_bytes=64 * 2**20, tensor_sizes=[2**20] * 64,
                    strategy="chaos")
    cl.train(2)
    res = cl.scale_out(99, {0: Link(400, 0.01), 2: Link(800, 0.004)})
    assert res.delay_s > 0 and 99 in cl.topo.active_nodes()
    r1 = cl.connect_link(99, 3, Link(500, 0.008))
    assert r1.delay_s < 1e-3
    r2 = cl.disconnect_link(99, 3)
    assert r2.delay_s < 1e-3
    cl.train(1)
    res_fail = cl.scale_in(99, failure=True)
    assert res_fail.delay_s < 1e-3
    assert 99 not in cl.topo.active_nodes()
    cl.train(1)  # cluster keeps training after the failure
