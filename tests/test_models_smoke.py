"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and finiteness (task spec §f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.configs.base import ShapeCell
from repro.models import build_model

SMOKE_CELL = ShapeCell("smoke", seq_len=16, global_batch=2, kind="train")
PREFILL_CELL = ShapeCell("smoke_prefill", seq_len=16, global_batch=2, kind="prefill")
DECODE_CELL = ShapeCell("smoke_decode", seq_len=16, global_batch=2, kind="decode")


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _reduced_model(name):
    cfg = get_config(name).reduced()
    return build_model(cfg)


@pytest.mark.parametrize("name", ASSIGNED)
def test_forward_and_loss(name, rng):
    model = _reduced_model(name)
    params = model.init(rng)
    batch = model.make_batch(SMOKE_CELL, rng)
    loss, metrics = jax.jit(lambda p, b: model.loss_fn(p, b))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{name}: non-finite loss {loss}"
    assert jnp.isfinite(metrics["loss"])


@pytest.mark.parametrize("name", ASSIGNED)
def test_train_step(name, rng):
    model = _reduced_model(name)
    state = model.init_train_state(rng)
    batch = model.make_batch(SMOKE_CELL, rng)
    step = jax.jit(model.make_train_step())
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # Params actually moved.
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("name", ASSIGNED)
def test_prefill_decode(name, rng):
    model = _reduced_model(name)
    cfg = model.cfg
    params = model.init(rng)
    batch = model.make_batch(PREFILL_CELL, rng)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b))(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, 1, cfg.vocab)  # prefill returns last-position logits
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    # One decode step continuing from the prefill cache.
    if cfg.family == "hybrid":
        cache = dict(cache)
    dec_batch = {
        "tokens": jnp.zeros((B, 1), jnp.int32),
        "pos": jnp.asarray(S - 1, jnp.int32),
        "cache": cache,
    }
    dlogits, _ = jax.jit(lambda p, b: model.decode_step(p, b))(params, dec_batch)
    assert dlogits.shape == (B, 1, cfg.vocab)
    assert jnp.all(jnp.isfinite(dlogits.astype(jnp.float32)))


@pytest.mark.parametrize("name", ASSIGNED)
def test_param_count_matches_analytic(name, rng):
    """Analytic param_count stays within 10% of the actually-initialized count
    (reduced config — catches drift between config math and model code)."""
    model = _reduced_model(name)
    params = model.init(rng)
    actual = sum(l.size for l in jax.tree.leaves(params))
    analytic = model.cfg.param_count()
    assert abs(actual - analytic) / max(actual, 1) < 0.25, (actual, analytic)
