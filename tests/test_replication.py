"""Replication engine: pytree ⇄ shard round-trips, manifests, codec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis optional: property tests skip, rest run
    def given(*a, **k):
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)

    def settings(*a, **k):
        return lambda fn: fn

    class _NoStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NoStrategies()

from repro.core.replication import (
    assemble_shards,
    build_manifest,
    execute_replication,
    extract_shards,
    flatten_state,
    make_shard_ranges,
    plan_replication,
    unflatten_state,
)
from repro.core.sharding_alg import NeighborLink
from repro.optim.compression import int8_dequantize, int8_quantize


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "params": {
            "w1": jax.random.normal(k, (17, 33), jnp.float32),
            "w2": jax.random.normal(jax.random.fold_in(k, 1), (8,), jnp.bfloat16),
        },
        "opt": {
            "m": jnp.zeros((17, 33), jnp.float32),
            "step": jnp.asarray(7, jnp.int32),
        },
    }


def test_flatten_roundtrip():
    t = _tree()
    buf, manifest = flatten_state(t)
    assert buf.nbytes == manifest.total_bytes
    t2 = unflatten_state(buf, manifest)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manifest_paths_and_sizes():
    t = _tree()
    m = build_manifest(t)
    paths = {e.path for e in m.entries}
    assert "params/w1" in paths and "opt/step" in paths
    assert sum(e.nbytes for e in m.entries) == m.total_bytes
    # Entries are contiguous and non-overlapping.
    off = 0
    for e in m.entries:
        assert e.offset == off
        off += e.nbytes


@settings(max_examples=50, deadline=None)
@given(total=st.integers(1, 10_000), s=st.integers(1, 4_000))
def test_shard_ranges_partition(total, s):
    rs = make_shard_ranges(total, s)
    assert rs[0].start == 0 and rs[-1].end == total
    for a, b in zip(rs, rs[1:]):
        assert a.end == b.start
    assert all(r.nbytes <= s for r in rs)


def test_shard_extract_assemble_roundtrip():
    buf = np.arange(1000, dtype=np.uint8)
    rs = make_shard_ranges(1000, 96)
    shards = extract_shards(buf, rs)
    out = assemble_shards(shards, rs, 1000)
    np.testing.assert_array_equal(buf, out)


def test_end_to_end_replication_exact():
    """A joining node reassembles bit-identical training state from
    multi-neighbor shard pulls (the paper's stop-free scale-out data path)."""
    t = _tree()
    neighbors = {
        10: NeighborLink(0.001, 1e-8, 0.0),
        11: NeighborLink(0.002, 2e-8, 0.1),
        12: NeighborLink(0.0005, 5e-8, 0.0),
    }
    plan = plan_replication(t, neighbors)
    rebuilt, by_source = execute_replication(t, plan)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # All sources ship disjoint shards covering the stream.
    seen = set()
    for shards in by_source.values():
        assert not (seen & set(shards))
        seen |= set(shards)
    assert seen == {r.index for r in plan.ranges}


def test_int8_codec_roundtrip_error():
    x = np.random.RandomState(0).randn(1000).astype(np.float32) * 3
    codes, scale, meta = int8_quantize(jnp.asarray(x))
    back = np.asarray(int8_dequantize(codes, scale, meta))
    err = np.abs(back - x).max()
    assert err <= np.abs(x).max() / 127.0 + 1e-6
