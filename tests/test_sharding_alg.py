"""Unit + property tests for the paper's Algorithms 1 & 2 and baselines."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis optional: property tests skip, rest run
    def given(*a, **k):
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)

    def settings(*a, **k):
        return lambda fn: fn

    class _NoStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NoStrategies()

from repro.core.sharding_alg import (
    NeighborLink,
    binary_search_assignment,
    brute_force_assignment,
    completion_time,
    even_assignment,
    greedy_shard_assignment,
    multi_source_plan,
    single_source_plan,
    chaos_plan,
)
from repro.core.topology import Link, Topology, random_edge_topology


def _nb(prop, bps, sync=0.0):
    return NeighborLink(prop, 1.0 / bps, sync)


# ---------------------------------------------------------------------------
# Algorithm 2 (greedy).
# ---------------------------------------------------------------------------


def test_greedy_balances_equal_links():
    nb = {0: _nb(0.0, 100.0), 1: _nb(0.0, 100.0)}
    asg = greedy_shard_assignment(10, 5, nb)
    counts = sorted(len(v) for v in asg.shards_per_neighbor.values())
    assert counts == [5, 5]


def test_greedy_prefers_fast_neighbor():
    nb = {0: _nb(0.0, 1000.0), 1: _nb(0.0, 10.0)}
    asg = greedy_shard_assignment(20, 5, nb)
    assert len(asg.shards_per_neighbor[0]) > len(asg.shards_per_neighbor[1])


def test_greedy_respects_sync_skew():
    """A neighbor still busy in all-reduce (large τ^sync) gets less work."""
    nb = {0: _nb(0.0, 100.0, sync=0.0), 1: _nb(0.0, 100.0, sync=100.0)}
    asg = greedy_shard_assignment(10, 10, nb)
    assert len(asg.shards_per_neighbor[0]) > len(asg.shards_per_neighbor[1])


def test_greedy_covers_all_shards_disjointly():
    nb = {i: _nb(0.001 * i, 50.0 + 10 * i) for i in range(4)}
    asg = greedy_shard_assignment(37, 3, nb)
    all_shards = sorted(k for v in asg.shards_per_neighbor.values() for k in v)
    assert all_shards == list(range(37))  # coverage + disjointness (Eq. 6)


@settings(max_examples=200, deadline=None)
@given(
    n_shards=st.integers(1, 24),
    s=st.integers(1, 1000),
    links=st.lists(
        st.tuples(st.floats(0, 0.1), st.floats(1e3, 1e9), st.floats(0, 1.0)),
        min_size=1, max_size=4,
    ),
)
def test_greedy_within_graham_bound(n_shards, s, links):
    """Algorithm 2 = LPT for P∥C_max ⇒ within (4/3 − 1/(3|U|))·OPT of the
    brute-force optimum on the *transmission* part. With per-neighbor offsets
    (prop+sync) the paper keeps the same bound empirically (Fig 16 ≤ 29%);
    we assert the Graham factor against the true optimum."""
    nb = {i: NeighborLink(p, 1.0 / b, y) for i, (p, b, y) in enumerate(links)}
    g = greedy_shard_assignment(n_shards, s, nb)
    opt = brute_force_assignment(n_shards, s, nb)
    bound = (4.0 / 3.0 - 1.0 / (3 * len(nb)))
    assert g.completion_s <= opt.completion_s * bound + 1e-9


@settings(max_examples=100, deadline=None)
@given(
    n_shards=st.integers(1, 30),
    s=st.integers(1, 100),
    links=st.lists(st.tuples(st.floats(0, 0.05), st.floats(1e3, 1e8)),
                   min_size=1, max_size=5),
)
def test_greedy_never_worse_than_even(n_shards, s, links):
    nb = {i: NeighborLink(p, 1.0 / b) for i, (p, b) in enumerate(links)}
    g = greedy_shard_assignment(n_shards, s, nb)
    e = even_assignment(n_shards, s, nb)
    assert g.completion_s <= e.completion_s + 1e-9


# ---------------------------------------------------------------------------
# Algorithm 1 (binary search over shard size).
# ---------------------------------------------------------------------------


def test_binary_search_improves_on_single_shard_granularity():
    sizes = [100] * 8 + [10_000]
    nb = {0: _nb(0.0, 1e4), 1: _nb(0.0, 1e4)}
    asg = binary_search_assignment(sizes, nb)
    # Two equal links: the optimum splits the 10.8kB state nearly in half.
    worst, _ = completion_time(
        {u: len(v) for u, v in asg.shards_per_neighbor.items()},
        asg.shard_size, nb)
    total = sum(sizes)
    lower = (total / 2) / 1e4
    assert worst <= 1.35 * lower


@settings(max_examples=50, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 10_000), min_size=1, max_size=30),
    links=st.lists(st.tuples(st.floats(0, 0.01), st.floats(1e4, 1e8)),
                   min_size=1, max_size=4),
)
def test_binary_search_covers_all_bytes(sizes, links):
    nb = {i: NeighborLink(p, 1.0 / b) for i, (p, b) in enumerate(links)}
    asg = binary_search_assignment(sizes, nb)
    total = sum(sizes)
    n_shards = asg.n_shards
    assert n_shards == math.ceil(total / asg.shard_size)
    # Objective is consistent with its own assignment.
    worst, _ = completion_time(
        {u: len(v) for u, v in asg.shards_per_neighbor.items()},
        asg.shard_size, nb)
    assert abs(worst - asg.completion_s) < 1e-9


# ---------------------------------------------------------------------------
# Plan-level comparisons (Fig 1 / Fig 15 qualitative claims).
# ---------------------------------------------------------------------------


def _mk_topo():
    topo = random_edge_topology(8, seed=3, degree=3)
    return topo


def test_multi_neighbor_beats_single_source_on_average():
    wins = 0
    trials = 10
    for seed in range(trials):
        topo = random_edge_topology(8, seed=seed, degree=3)
        new = max(topo.nodes) + 1
        topo.add_node(new)
        import random as _r
        rng = _r.Random(seed)
        for peer in rng.sample(sorted(set(topo.nodes) - {new}), 3):
            topo.add_link(new, peer, Link(rng.uniform(100, 1000),
                                          rng.uniform(0.001, 0.02)))
        state = 500 * 1024 * 1024
        sizes = [4 * 1024 * 1024] * 125
        c = chaos_plan(topo, new, state, sizes)
        s = single_source_plan(topo, new, state)
        if c.predicted_delay_s <= s.predicted_delay_s + 1e-9:
            wins += 1
    assert wins >= 8, f"chaos won only {wins}/{trials} vs single-source"


def test_multi_source_suffers_multihop():
    """Fig 1c: multi-source pulls from distant nodes over multi-hop paths."""
    topo = random_edge_topology(10, seed=1, degree=2)
    new = 10
    topo.add_node(new)
    topo.add_link(new, 0, Link(500, 0.005))
    topo.add_link(new, 1, Link(400, 0.005))
    state = 500 * 1024 * 1024
    sizes = [4 * 1024 * 1024] * 125
    c = chaos_plan(topo, new, state, sizes)
    m = multi_source_plan(topo, new, state)
    assert c.predicted_delay_s < m.predicted_delay_s


def test_chaos_plan_sources_are_neighbors_only():
    topo = _mk_topo()
    new = 8
    topo.add_node(new)
    topo.add_link(new, 0, Link(300, 0.01))
    topo.add_link(new, 3, Link(800, 0.002))
    plan = chaos_plan(topo, new, 10**8, [10**6] * 100)
    assert set(plan.sources) <= {0, 3}
    for route in plan.routes.values():
        assert len(route) == 2  # direct neighbor links, no multi-hop
