"""Causal span tracing + metrics exposition (the observability PR's suite).

Four contracts:

1. **Well-formedness** — on every scenario generator family, the stitched
   span forest validates clean: every ``*-started`` record reaches exactly
   one terminal, children sit inside parents, same-name siblings never
   overlap; and the forest's own BadPut windows classify to *exactly* the
   GoodputReport's components (`fsum`-level equality, same code path).
2. **Determinism** — same seed ⇒ byte-identical span digest and
   byte-identical ``metrics.prom`` exposition.
3. **Inertness** — telemetry is a pure post-hoc read: running the full
   pipeline (spans, Chrome trace, metrics) against the pinned omniscient
   poisson replay leaves the ledger digest at the pre-reshard constant.
4. **Cross-substrate parity** — the simulator and a TrainerBackend replay
   of one ``mixed_faults`` trace reach the same span digest.
"""
import json
import sys
from pathlib import Path

import pytest

from repro.core import SimCluster, random_edge_topology
from repro.core.engine import (
    ChurnEngine,
    EventLedger,
    SimBackend,
    run_trace_goodput,
    run_trace_sim,
)
from repro.core.goodput import goodput_report
from repro.core.telemetry import (
    DETECTION_BUCKETS,
    TTR_BUCKETS,
    Counter,
    Histogram,
    MetricsRegistry,
    build_spans,
    collect_backend,
    detection_rows,
    markdown_report,
    span_digest,
    trace_events,
    ttr_rows,
    validate,
    validate_trace_events,
    write_chrome_trace,
)
from repro.scenarios import (
    adversarial_churn,
    checkpointed_training,
    detector_stress,
    mixed_faults,
    poisson_churn,
    reshard_churn,
    scheduler_churn,
)

from test_resharding import MB, PRE_RESHARD_DIGEST, _poisson_cluster_and_trace

ROOT = Path(__file__).resolve().parent.parent


def _cluster(n=10, seed=3):
    cl = SimCluster(random_edge_topology(n, seed=seed),
                    state_bytes=16 * MB, tensor_sizes=[MB] * 16)
    cl.train(1)
    return cl


def _scenarios():
    """One (name, trace, engine kwargs) per generator family the issue
    names; sized small enough for tier-1."""
    topo = random_edge_topology(10, seed=3)
    nodes = sorted(topo.active_nodes())
    return [
        ("poisson", poisson_churn(nodes, seed=7, horizon_s=120.0,
                                  rate_join=0.05, rate_leave=0.04), {}),
        ("adversarial", adversarial_churn(nodes, seed=7, horizon_s=90.0,
                                          n_joins=4), {}),
        ("detector_stress", detector_stress(topo, seed=7, horizon_s=60.0),
         {}),
        ("scheduler_churn", scheduler_churn(topo, seed=7, horizon_s=60.0),
         {}),
        ("reshard_churn", reshard_churn(nodes, seed=4, n_failures=3,
                                        n_joins=1),
         {"reshard": "auto"}),
        ("mixed_faults", mixed_faults(topo, seed=5, horizon_s=90.0), {}),
        ("checkpointed", checkpointed_training(nodes, seed=7,
                                               horizon_s=80.0),
         {"checkpoint": "adaptive", "policy": "fixed-checkpoint"}),
    ]


def _replay(trace, **kw):
    cl = _cluster()
    return run_trace_goodput(cl, list(trace), **kw)


@pytest.mark.parametrize("name,trace,kw",
                         _scenarios(), ids=lambda v: v if isinstance(v, str)
                         else "")
def test_span_wellformedness_and_conservation(name, trace, kw):
    ledger, _, report = _replay(trace, **kw)
    forest = build_spans(ledger, t_start=report.t_start, t_end=report.t_end)
    assert validate(ledger, forest) == []
    # The forest's own windows classify to exactly the accounting's
    # components — same classifier, same fsum order, bit-equal.
    assert forest.badput_components() == report.components
    # Exported trace passes the trace_event schema audit.
    assert validate_trace_events(trace_events(forest)) == []


@pytest.mark.parametrize("name,trace,kw",
                         _scenarios(), ids=lambda v: v if isinstance(v, str)
                         else "")
def test_same_seed_span_digest_byte_identity(name, trace, kw):
    d1 = span_digest(_replay(trace, **kw)[0])
    d2 = span_digest(_replay(trace, **kw)[0])
    assert d1 == d2


def test_pinned_poisson_digest_inert_under_full_telemetry(tmp_path):
    """Running the entire telemetry pipeline — accounting, span forest,
    Chrome trace export, metrics scrape, markdown report — against the
    seeded omniscient poisson replay leaves the ledger at the pre-reshard
    pinned digest. Telemetry cannot change a ledger byte."""
    cl, trace = _poisson_cluster_and_trace()
    backend = SimBackend(cl, accounting=True)
    ledger = ChurnEngine(backend).run(list(trace))
    assert ledger.digest() == PRE_RESHARD_DIGEST
    report = backend.goodput
    forest = build_spans(ledger, t_start=report.t_start, t_end=report.t_end)
    assert validate(ledger, forest) == []
    write_chrome_trace(tmp_path / "chaos-trace.json", forest)
    reg = MetricsRegistry()
    collect_backend(reg, backend, ledger, report=report)
    (tmp_path / "metrics.prom").write_text(reg.exposition())
    markdown_report(ledger, forest, report=report)
    span_digest(ledger, forest)
    assert ledger.digest() == PRE_RESHARD_DIGEST
    # And a plain replay (telemetry never constructed) agrees.
    cl2, trace2 = _poisson_cluster_and_trace()
    ledger2, _ = run_trace_sim(cl2, trace2)
    assert ledger2.digest() == PRE_RESHARD_DIGEST


def test_metrics_prom_byte_stable_and_has_ttr_histograms():
    topo = random_edge_topology(10, seed=3)
    trace = mixed_faults(topo, seed=5, horizon_s=90.0)

    def scrape():
        cl = _cluster()
        backend = SimBackend(cl, accounting=True)
        ledger = ChurnEngine(backend).run(list(trace))
        reg = MetricsRegistry()
        collect_backend(reg, backend, ledger, report=backend.goodput)
        return reg.exposition()

    prom1, prom2 = scrape(), scrape()
    assert prom1 == prom2  # byte-stable across same-seed replays
    assert "# TYPE chaos_engine_ttr_seconds histogram" in prom1
    assert 'chaos_engine_ttr_seconds_bucket{fault_class="node-failure"' \
        in prom1
    assert 'fault_class="scheduler-failure"' in prom1
    assert "chaos_monitor_detection_latency_seconds_bucket" in prom1
    # Exposition is sorted by family name — no dict-order dependence.
    families = [ln.split()[2] for ln in prom1.splitlines()
                if ln.startswith("# TYPE")]
    assert families == sorted(families)


def test_histogram_buckets_cumulative_and_deterministic():
    h = Histogram("t_seconds", "", ("cls",), buckets=(1.0, 0.1, 10.0))
    assert h.edges == (0.1, 1.0, 10.0)  # sorted regardless of input order
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v, cls="x")
    lines = h.expose()
    assert lines == [
        't_seconds_bucket{cls="x",le="0.1"} 1',
        't_seconds_bucket{cls="x",le="1"} 3',
        't_seconds_bucket{cls="x",le="10"} 4',
        't_seconds_bucket{cls="x",le="+Inf"} 5',
        't_seconds_sum{cls="x"} 56.05',
        't_seconds_count{cls="x"} 5',
    ]


def test_registry_order_independent_and_validating():
    def fill(pairs):
        reg = MetricsRegistry()
        for name, labels in pairs:
            reg.counter(name, "h", ("k",)).inc(1.0, k=labels)
        return reg.exposition()

    a = fill([("m_b", "x"), ("m_a", "y"), ("m_b", "a")])
    b = fill([("m_a", "y"), ("m_b", "a"), ("m_b", "x")])
    assert a == b
    with pytest.raises(ValueError):
        Counter("0bad name")
    with pytest.raises(ValueError):
        Counter("ok", label_names=("bad-label",))
    with pytest.raises(ValueError):
        Counter("c").inc(-1.0)
    reg = MetricsRegistry()
    reg.counter("m", "h", ("k",))
    with pytest.raises(ValueError):
        reg.gauge("m", "h", ("k",))  # type change rejected
    with pytest.raises(ValueError):
        reg.counter("m", "h", ("other",))  # label change rejected


def test_unclosed_started_records_are_flagged():
    led = EventLedger()
    led.append(0, 1.0, "join", 100, "scale-out-started", {})
    v = validate(led)
    assert any("join" in x and "1 started, 0 terminal" in x for x in v)
    led.append(0, 2.0, "join", 100, "ready", {})
    assert validate(led) == []
    led.append(1, 3.0, "reshard", 5, "reshard-started",
               {"old_shape": (4, 1), "new_shape": (2, 2), "moved_bytes": 0,
                "step_s": 1.0, "baseline_step_s": 1.0})
    assert any("reshard" in x for x in validate(led))
    led.append(2, 4.0, "node-fault", 7, "fault-injected", {})
    v = validate(led)
    assert any("fault seq=2" in x for x in v)


def test_trace_event_schema_negatives():
    assert validate_trace_events(
        [{"ph": "Z", "name": "x"}]) != []
    assert any("flow id" in v for v in validate_trace_events(
        [{"ph": "s", "name": "f", "pid": 1, "tid": 1, "ts": 0, "id": 9}]))
    bad_ts = validate_trace_events(
        [{"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": -5, "dur": 1}])
    assert any("bad ts" in v for v in bad_ts)


def test_chrome_trace_export_loadable_shape(tmp_path):
    topo = random_edge_topology(10, seed=3)
    trace = mixed_faults(topo, seed=5, horizon_s=90.0)
    ledger, _, report = _replay(trace)
    forest = build_spans(ledger, t_start=report.t_start, t_end=report.t_end)
    path = write_chrome_trace(tmp_path / "chaos-trace.json", forest)
    data = json.loads(Path(path).read_text())
    evs = data["traceEvents"]
    assert {e["ph"] for e in evs} >= {"X", "M"}
    procs = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert procs == {"control-plane", "nodes", "links"}
    # Byte-determinism of the artifact itself.
    blob1 = Path(path).read_text()
    write_chrome_trace(tmp_path / "again.json", forest)
    assert (tmp_path / "again.json").read_text() == blob1


def test_cross_substrate_span_digest_parity():
    """One mixed_faults trace, two substrates, one span digest: the
    simulator's detection-driven replay and the TrainerBackend's
    event-boundary replay collapse to the same (seq, kind, subject, fate)
    stream."""
    sys.path.insert(0, str(ROOT))
    from tools.trace_report import _MembershipTrainer
    from repro.elastic.trainer import TrainerBackend

    topo = random_edge_topology(12, seed=1)
    trace = mixed_faults(topo, seed=5, horizon_s=120.0)
    cl = SimCluster(topo, state_bytes=32 * MB, tensor_sizes=[MB] * 32)
    cl.train(1)
    sim_ledger, _ = run_trace_sim(cl, list(trace))

    tr = _MembershipTrainer(sorted(random_edge_topology(12, seed=1)
                                   .active_nodes()))
    backend = TrainerBackend(tr, min_active=2, state_bytes=32 * MB,
                             tensor_sizes=[MB] * 32)
    tr_ledger = ChurnEngine(backend).run(list(trace))

    assert span_digest(sim_ledger) == span_digest(tr_ledger)
    # The raw ledgers genuinely differ (virtual times, detection detail) —
    # parity is the projection's work, not an artifact of equal inputs.
    assert sim_ledger.canonical_bytes() != tr_ledger.canonical_bytes()


def test_trace_report_cli_smoke(tmp_path):
    sys.path.insert(0, str(ROOT))
    from tools.trace_report import main

    assert main(["--smoke", "--out", str(tmp_path)]) == 0
    assert (tmp_path / "chaos-trace.json").exists()
    prom = (tmp_path / "metrics.prom").read_text()
    assert "chaos_engine_ttr_seconds_bucket" in prom
    assert (tmp_path / "report.md").read_text().startswith("# Chaos trace")


def test_detection_rows_single_source_of_truth():
    """benchmarks.common.detection_rows IS the telemetry implementation,
    and the span forest carries the same rows."""
    sys.path.insert(0, str(ROOT))
    from benchmarks import common

    assert common.detection_rows is detection_rows
    topo = random_edge_topology(10, seed=3)
    trace = mixed_faults(topo, seed=5, horizon_s=90.0)
    ledger, _, report = _replay(trace)
    forest = build_spans(ledger, t_start=report.t_start, t_end=report.t_end)
    assert forest.rows == detection_rows(ledger)
    rows = ttr_rows(ledger)
    assert rows and all(r["ttr_s"] >= 0 for r in rows)
    assert {r["fault_class"] for r in rows} <= {
        "node-failure", "link-failure", "scheduler-failure"}


def test_bucket_edges_are_pinned():
    """Bucket edges are constants, never derived from observed data — the
    byte-stability of metrics.prom rests on this."""
    assert TTR_BUCKETS == tuple(sorted(TTR_BUCKETS))
    assert DETECTION_BUCKETS == tuple(sorted(DETECTION_BUCKETS))
    assert TTR_BUCKETS[0] == 0.01 and TTR_BUCKETS[-1] == 300.0
