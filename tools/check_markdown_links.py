#!/usr/bin/env python3
"""Markdown link check for the docs CI job (stdlib only, no network).

Verifies that every relative link target in the given markdown files (or
every ``*.md`` under given directories) exists in the repository. External
``http(s)://`` / ``mailto:`` links are skipped — CI has no business
depending on the network — and ``#anchor`` fragments are stripped before
the existence check.

Usage:
    python tools/check_markdown_links.py README.md docs src/repro/scenarios/README.md
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) — won't match images' leading "!" specially; that's fine,
# image targets must exist too. Ignores targets containing spaces-only.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files(args: list[str]) -> list[Path]:
    out: list[Path] = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.md")))
        else:
            out.append(p)
    return out


def check(paths: list[Path]) -> list[str]:
    errors = []
    for md in paths:
        if not md.exists():
            errors.append(f"{md}: file not found")
            continue
        for n, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(SKIP_PREFIXES):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                resolved = (md.parent / rel).resolve()
                if not resolved.exists():
                    errors.append(f"{md}:{n}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    paths = md_files(argv or ["README.md", "docs", "ROADMAP.md"])
    errors = check(paths)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(paths)} markdown file(s): "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
