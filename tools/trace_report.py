#!/usr/bin/env python
"""Replay a churn trace and emit the observability artifacts.

Outputs (default ``benchmarks/results/trace_report/``):

* ``chaos-trace.json`` — Chrome ``trace_event`` JSON on the virtual clock
  (open it at ``ui.perfetto.dev`` or ``chrome://tracing``);
* ``metrics.prom`` — Prometheus text exposition of the run's counters,
  gauges, and per-fault-class TTR histograms (byte-stable per seed);
* ``report.md`` — markdown timeline + TTR/GoodPut summary.

``--assert-inert`` proves the telemetry-is-inert invariant on this trace:
the ledger digest with telemetry enabled equals a plain replay's, a second
telemetry replay reproduces ``metrics.prom`` byte-for-byte, and the span
digest is stable. ``--parity`` additionally replays the same trace through
a (membership-only) :class:`~repro.elastic.trainer.TrainerBackend` and
asserts span-digest equality across the substrates. ``--expect-digest``
pins the replay against a known ledger digest (CI uses the pre-reshard
omniscient poisson digest from ``tests/test_resharding.py``).

Usage::

    PYTHONPATH=src python tools/trace_report.py --smoke
    PYTHONPATH=src python tools/trace_report.py \
        --generator mixed-faults --seed 5 --horizon 120 --parity
    PYTHONPATH=src python tools/trace_report.py --trace my_trace.jsonl
"""
from __future__ import annotations

import argparse
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT / "src") not in sys.path:
    sys.path.insert(0, str(ROOT / "src"))

from repro.core.engine import ChurnEngine, SimBackend  # noqa: E402
from repro.core.goodput import goodput_report  # noqa: E402
from repro.core.negotiation import SimCluster  # noqa: E402
from repro.core.telemetry import (  # noqa: E402
    MetricsRegistry,
    build_spans,
    collect_backend,
    collect_trainer_backend,
    markdown_report,
    span_digest,
    trace_events,
    validate,
    validate_trace_events,
    write_chrome_trace,
)
from repro.core.topology import random_edge_topology  # noqa: E402
from repro.scenarios.generators import (  # noqa: E402
    mixed_faults,
    poisson_churn,
)
from repro.scenarios.trace import ScenarioTrace  # noqa: E402

MB = 1 << 20
DEFAULT_OUT = ROOT / "benchmarks" / "results" / "trace_report"


def _build_cluster(args):
    topo = random_edge_topology(args.nodes, seed=args.topo_seed)
    cl = SimCluster(topo, state_bytes=args.state_mb * MB,
                    tensor_sizes=[MB] * args.state_mb)
    cl.train(1)
    return topo, cl


def _build_trace(args, topo):
    if args.trace:
        return ScenarioTrace.load(args.trace)
    if args.generator == "mixed-faults":
        return mixed_faults(topo, seed=args.seed, horizon_s=args.horizon,
                            n_joins=args.joins)
    if args.generator == "poisson-churn":
        return poisson_churn(sorted(topo.active_nodes()), seed=args.seed,
                             horizon_s=args.horizon,
                             rate_join=0.05, rate_leave=0.04)
    raise SystemExit(f"unknown generator {args.generator!r} "
                     f"(use --trace for other scenarios)")


def _sim_replay(args, *, telemetry: bool):
    """One fresh replay of the configured trace. With ``telemetry`` the
    backend is scraped and the span forest built — the inertness check
    compares this replay's ledger digest against a plain one's."""
    topo, cl = _build_cluster(args)
    trace = _build_trace(args, topo)
    backend = SimBackend(cl, min_active=2, policy=args.policy,
                         accounting=True)
    ledger = ChurnEngine(backend).run(list(trace))
    if not telemetry:
        return ledger.digest(), None, None, None
    report = backend.goodput
    forest = build_spans(ledger, t_start=report.t_start, t_end=report.t_end)
    reg = MetricsRegistry()
    collect_backend(reg, backend, ledger, report=report)
    return ledger.digest(), ledger, forest, reg


class _Dev:
    def __init__(self, i):
        self.id = i


class _MembershipTrainer:
    """Membership-only ElasticTrainer double (the established test idiom):
    enough surface for TrainerBackend's event handling without jax arrays.
    ``spare`` free pool devices let trace joins complete."""

    def __init__(self, node_ids, spare=4):
        top = max(node_ids) + 1 if node_ids else 0
        self.pool = [_Dev(i) for i in node_ids] + \
            [_Dev(top + k) for k in range(spare)]
        self.active = [d for d in self.pool if d.id in set(node_ids)]
        self.step_count = 0

    def scale_in(self, device, failure=False):
        self.active.remove(device)
        return type("E", (), {"step": self.step_count})()

    def scale_out(self, device, codec=None):
        self.active.append(device)
        return type("E", (), {
            "step": self.step_count,
            "plan_summary": {"n_shards": len(self.active), "shard_size": 0},
        })()

    def apply_reshard(self, tp, microbatch=1):
        return type("E", (), {"step": self.step_count})()

    def apply_link_event(self, kind, device_ids, **kw):
        pass


def _trainer_replay(args):
    """Replay the same trace through TrainerBackend; returns its span
    digest (times differ by construction — the digest must not)."""
    from repro.elastic.trainer import TrainerBackend

    topo, _cl = _build_cluster(args)
    trace = _build_trace(args, topo)
    tr = _MembershipTrainer(sorted(topo.active_nodes()))
    backend = TrainerBackend(tr, min_active=2, policy=args.policy,
                             state_bytes=args.state_mb * MB,
                             tensor_sizes=[MB] * args.state_mb)
    ledger = ChurnEngine(backend).run(list(trace))
    t_end = max((r.t for r in ledger), default=0.0)
    report = goodput_report(ledger, t_start=0.0, t_end=t_end)
    forest = build_spans(ledger, t_start=0.0, t_end=t_end)
    reg = MetricsRegistry()
    collect_trainer_backend(reg, backend, ledger, report=report)
    return span_digest(ledger, forest), reg.exposition()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--generator", default="mixed-faults",
                    choices=["mixed-faults", "poisson-churn"])
    ap.add_argument("--trace", default=None,
                    help="replay a saved ScenarioTrace JSONL instead")
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--topo-seed", type=int, default=1)
    ap.add_argument("--nodes", type=int, default=12)
    ap.add_argument("--horizon", type=float, default=120.0)
    ap.add_argument("--joins", type=int, default=2)
    ap.add_argument("--state-mb", type=int, default=32)
    ap.add_argument("--policy", default="fixed")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    ap.add_argument("--assert-inert", action="store_true",
                    help="prove digest-inertness + metrics byte-stability")
    ap.add_argument("--parity", action="store_true",
                    help="assert sim/trainer span-digest parity")
    ap.add_argument("--expect-digest", default=None,
                    help="fail unless the replay's ledger digest equals this")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: --assert-inert --parity + schema checks")
    args = ap.parse_args(argv)
    if args.smoke:
        args.assert_inert = True
        args.parity = True

    digest, ledger, forest, reg = _sim_replay(args, telemetry=True)
    print(f"replayed {len(list(ledger))} ledger records; "
          f"ledger digest {digest[:16]}…")
    if args.expect_digest and digest != args.expect_digest:
        print(f"FAIL: ledger digest {digest} != expected "
              f"{args.expect_digest}")
        return 1

    violations = validate(ledger, forest)
    if violations:
        for v in violations:
            print(f"  span violation: {v}")
        return 1
    report = goodput_report(ledger, t_start=forest.t_start,
                            t_end=forest.t_end)
    if forest.badput_components() != report.components:
        print("FAIL: span intervals do not conserve against GoodputReport")
        return 1
    sdigest = span_digest(ledger, forest)
    print(f"span forest: {len(forest.roots)} roots, "
          f"{len(forest.flows)} flows, 0 violations; "
          f"span digest {sdigest[:16]}…")

    events = trace_events(forest)
    schema = validate_trace_events(events)
    if schema:
        for v in schema:
            print(f"  trace_event violation: {v}")
        return 1

    args.out.mkdir(parents=True, exist_ok=True)
    trace_path = write_chrome_trace(
        args.out / "chaos-trace.json", forest,
        metadata={"generator": args.generator, "seed": args.seed,
                  "ledger_digest": digest, "span_digest": sdigest})
    prom = reg.exposition()
    (args.out / "metrics.prom").write_text(prom)
    (args.out / "report.md").write_text(markdown_report(
        ledger, forest, report=report,
        title=f"Chaos trace report — {args.generator} seed={args.seed}"))
    print(f"wrote {trace_path}, metrics.prom ({len(prom)} bytes), report.md")

    if args.assert_inert:
        plain_digest, _, _, _ = _sim_replay(args, telemetry=False)
        if plain_digest != digest:
            print(f"FAIL: telemetry changed the ledger "
                  f"({plain_digest} != {digest})")
            return 1
        digest2, ledger2, forest2, reg2 = _sim_replay(args, telemetry=True)
        if reg2.exposition() != prom:
            print("FAIL: metrics.prom not byte-stable across replays")
            return 1
        if span_digest(ledger2, forest2) != sdigest:
            print("FAIL: span digest not stable across replays")
            return 1
        print("inertness: telemetry replay is ledger-byte-identical; "
              "metrics.prom and span digest byte-stable")

    if args.parity:
        tr_digest, _tr_prom = _trainer_replay(args)
        if tr_digest != sdigest:
            print(f"FAIL: trainer span digest {tr_digest} != simulator "
                  f"{sdigest}")
            return 1
        print("parity: TrainerBackend replay reaches the same span digest")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
